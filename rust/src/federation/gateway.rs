//! The federation gateway: batched ingestion, deterministic routing,
//! lock-step advancement and cross-scheduler work stealing.

use std::collections::VecDeque;

use crate::obs::{Obs, ObsSnapshot, TraceKind};
use crate::scheduler::{JobId, JobSpec, SchedEvent, SchedulerSim};
use crate::sim::{self, EventQueue, Time};
use crate::workload::contention::Submission;

use super::outcome::{FederationOutcome, InstanceReport, JobReport, LatencySummary};
use super::FederationConfig;

/// One scheduler instance behind the gateway: the sim, its private
/// event calendar, the gateway-side submission buffer and counters.
struct Instance {
    sim: SchedulerSim,
    q: EventQueue<SchedEvent>,
    /// Gateway job indices buffered here, not yet injected.
    buf: Vec<usize>,
    /// Tasks across the buffered jobs (so routing sees buffered load).
    buf_tasks: usize,
    /// Gateway job indices currently owned here, oldest first — the
    /// steal pass scans from the front and drops entries the moment a
    /// withdrawal is refused (a refused job has started work and can
    /// never become fully pending again).
    candidates: VecDeque<usize>,
    routed: u64,
    batches: u64,
    stolen_in: u64,
    stolen_out: u64,
    pending_peak: usize,
    /// DES events processed across all lock-step windows.
    events: u64,
}

/// One gateway job: the retained spec (for steal re-submission), its
/// gateway arrival, and where it currently lives.
struct GatewayJob {
    spec: JobSpec,
    class: crate::workload::contention::JobClass,
    submit_t: Time,
    /// Current owning instance.
    owner: usize,
    /// Job id *within* the owner (re-assigned on every steal).
    inst_job: JobId,
    steals: u32,
}

/// The submission gateway over a fleet of independent schedulers.
///
/// Construct with the per-partition sims (each already configured over
/// its own disjoint cluster), then [`Gateway::run`] a time-sorted
/// submission stream to completion. See the module docs for the
/// lock-step discipline.
pub struct Gateway {
    cfg: FederationConfig,
    insts: Vec<Instance>,
    jobs: Vec<GatewayJob>,
    /// Round-robin cursor breaking least-backlog ties.
    rr: usize,
    steals: u64,
    batches: u64,
    /// Gateway-side flight recorder (routing, flushes, steal traffic).
    /// `None` keeps every trace site down to a single branch.
    obs: Option<Box<Obs>>,
}

impl Gateway {
    /// Build a gateway over the given instances. `cfg.instances` must
    /// match the number of sims (the config names the fleet shape; the
    /// sims are the fleet).
    pub fn new(cfg: FederationConfig, sims: Vec<SchedulerSim>) -> Gateway {
        assert!(!sims.is_empty(), "gateway needs at least one instance");
        assert_eq!(
            cfg.instances,
            sims.len(),
            "federation.instances must match the sims provided"
        );
        let insts = sims
            .into_iter()
            .map(|sim| Instance {
                sim,
                q: EventQueue::new(),
                buf: Vec::new(),
                buf_tasks: 0,
                candidates: VecDeque::new(),
                routed: 0,
                batches: 0,
                stolen_in: 0,
                stolen_out: 0,
                pending_peak: 0,
                events: 0,
            })
            .collect();
        Gateway {
            cfg,
            insts,
            jobs: Vec::new(),
            rr: 0,
            steals: 0,
            batches: 0,
            obs: None,
        }
    }

    /// Install a gateway-side flight recorder. Per-instance recorders
    /// are installed on the sims before construction; [`Gateway::run`]
    /// merges every part into one fleet-wide snapshot on the outcome.
    pub fn with_recorder(mut self, obs: Box<Obs>) -> Gateway {
        self.obs = Some(obs);
        self
    }

    /// Record one gateway flight-recorder event (no-op when off).
    #[inline]
    fn trace(&mut self, kind: TraceKind, unit: u32, id: u64, t: Time, detail: i64) {
        if let Some(o) = self.obs.as_mut() {
            o.record(kind, unit, id, t, detail);
        }
    }

    /// Drive the fleet over a time-sorted submission stream until every
    /// instance's calendar drains, then assemble the rollup.
    pub fn run(mut self, subs: Vec<Submission>) -> FederationOutcome {
        debug_assert!(
            subs.windows(2).all(|w| w[0].at <= w[1].at),
            "submissions must be time-sorted"
        );
        for inst in &mut self.insts {
            inst.sim.prepare(&mut inst.q);
        }
        self.jobs.reserve(subs.len());
        let mut tick = self.cfg.flush_interval;
        let mut si = 0;
        while si < subs.len() {
            let t_sub = subs[si].at;
            if t_sub < tick {
                // Submission boundary: advance strictly before the
                // arrival instant, then inject — so the new Submit
                // events play at their true time, after everything that
                // already happened and before anything later.
                self.advance_all(t_sub);
                while si < subs.len() && subs[si].at == t_sub {
                    let sub = subs[si].clone();
                    self.route(sub, t_sub);
                    si += 1;
                }
            } else {
                self.boundary_tick(tick);
                tick += self.cfg.flush_interval;
            }
        }
        // Drain: keep ticking (flushing stragglers, stealing across the
        // shrinking backlogs) until every calendar is empty and every
        // buffer flushed.
        loop {
            self.boundary_tick(tick);
            let live = self.insts.iter_mut().any(|i| i.q.peek_time().is_some());
            if !live && self.insts.iter().all(|i| i.buf.is_empty()) {
                break;
            }
            tick += self.cfg.flush_interval;
        }
        self.finish()
    }

    /// One flush tick: advance everyone strictly before the tick, flush
    /// all buffers, then rebalance.
    fn boundary_tick(&mut self, t: Time) {
        self.advance_all(t);
        for i in 0..self.insts.len() {
            self.flush(i, t);
        }
        self.steal_pass(t);
    }

    /// Advance every instance strictly up to `t` (lock-step window).
    fn advance_all(&mut self, t: Time) {
        for inst in &mut self.insts {
            let (_, n) = sim::run_until_before(&mut inst.sim, &mut inst.q, t);
            inst.events += n;
            let depth = inst.sim.pending_depth();
            if depth > inst.pending_peak {
                inst.pending_peak = depth;
            }
        }
    }

    /// Route one submission: least backlog (queued + buffered tasks),
    /// round-robin cursor on ties. Flushes the target's buffer early
    /// when it reaches the batch size.
    fn route(&mut self, sub: Submission, now: Time) {
        let n = self.insts.len();
        let mut best = self.rr % n;
        let mut best_load = usize::MAX;
        for k in 0..n {
            let i = (self.rr + k) % n;
            let load = self.insts[i].sim.pending_depth() + self.insts[i].buf_tasks;
            if load < best_load {
                best_load = load;
                best = i;
            }
        }
        self.rr = (best + 1) % n;
        let idx = self.jobs.len();
        let buf_tasks = sub.spec.tasks.len();
        self.jobs.push(GatewayJob {
            spec: sub.spec,
            class: sub.class,
            submit_t: sub.at,
            owner: best,
            inst_job: 0,
            steals: 0,
        });
        self.trace(TraceKind::GatewayRoute, best as u32, idx as u64, now, best_load as i64);
        let inst = &mut self.insts[best];
        inst.routed += 1;
        inst.buf.push(idx);
        inst.buf_tasks += buf_tasks;
        if inst.buf.len() >= self.cfg.batch {
            self.flush(best, now);
        }
    }

    /// Inject instance `i`'s buffered jobs at time `t` as one batch.
    fn flush(&mut self, i: usize, t: Time) {
        if self.insts[i].buf.is_empty() {
            return;
        }
        let buf = std::mem::take(&mut self.insts[i].buf);
        self.insts[i].buf_tasks = 0;
        let flushed = buf.len();
        for idx in buf {
            let spec = self.jobs[idx].spec.clone();
            let inst = &mut self.insts[i];
            let id = inst.sim.submit_at(&mut inst.q, t, spec);
            self.jobs[idx].inst_job = id;
            inst.candidates.push_back(idx);
            // Cross-process join key for the span layer: gateway job
            // `idx` now lives on instance `i` as local job `id`.
            self.trace(TraceKind::JobLink, i as u32, idx as u64, t, id as i64);
        }
        self.insts[i].batches += 1;
        self.batches += 1;
        let batch_ord = self.insts[i].batches;
        self.trace(TraceKind::GatewayFlush, i as u32, batch_ord, t, flushed as i64);
    }

    /// One steal pass at a tick boundary: while the deepest backlog
    /// exceeds the threshold and meaningfully exceeds the shallowest,
    /// migrate one still-queued job from the former to the latter.
    /// Depths are tracked locally across the pass (a resubmitted job's
    /// tasks only enter the receiver's queues after its Register op),
    /// so one pass converges instead of dog-piling a single receiver.
    fn steal_pass(&mut self, t: Time) {
        let n = self.insts.len();
        if n < 2 {
            return;
        }
        let mut depths: Vec<usize> = self
            .insts
            .iter()
            .map(|i| i.sim.pending_depth() + i.buf_tasks)
            .collect();
        loop {
            let (donor, &dmax) = depths
                .iter()
                .enumerate()
                .max_by_key(|&(i, &d)| (d, std::cmp::Reverse(i)))
                .expect("non-empty fleet");
            let (recv, &dmin) = depths
                .iter()
                .enumerate()
                .min_by_key(|&(i, &d)| (d, i))
                .expect("non-empty fleet");
            if dmax <= self.cfg.steal_threshold || dmax - dmin < 2 {
                break;
            }
            match self.steal_one(donor, recv, t) {
                Some(moved_tasks) => {
                    depths[donor] = depths[donor].saturating_sub(moved_tasks);
                    depths[recv] += moved_tasks;
                }
                None => break,
            }
        }
    }

    /// Migrate the oldest stealable job from `donor` to `recv`. Returns
    /// the number of tasks moved, or `None` when the donor has no
    /// withdrawable job left. Refused candidates (already started,
    /// mid-dispatch, or finished) are dropped permanently — a job that
    /// has touched a node never becomes fully pending again.
    fn steal_one(&mut self, donor: usize, recv: usize, t: Time) -> Option<usize> {
        while let Some(idx) = self.insts[donor].candidates.pop_front() {
            if self.jobs[idx].owner != donor {
                continue; // stale entry from an earlier migration
            }
            let inst_job = self.jobs[idx].inst_job;
            if !self.insts[donor].sim.withdraw_job(t, inst_job) {
                self.trace(TraceKind::StealRefused, donor as u32, idx as u64, t, recv as i64);
                continue;
            }
            let spec = self.jobs[idx].spec.clone();
            let moved = spec.tasks.len();
            let inst = &mut self.insts[recv];
            let id = inst.sim.submit_at(&mut inst.q, t, spec);
            inst.candidates.push_back(idx);
            inst.stolen_in += 1;
            self.insts[donor].stolen_out += 1;
            self.jobs[idx].owner = recv;
            self.jobs[idx].inst_job = id;
            self.jobs[idx].steals += 1;
            self.steals += 1;
            self.trace(TraceKind::StealAttempt, donor as u32, idx as u64, t, recv as i64);
            // Re-bind the join key: the job's local id changed hands.
            self.trace(TraceKind::JobLink, recv as u32, idx as u64, t, id as i64);
            return Some(moved);
        }
        None
    }

    /// Finish every instance and roll the fleet up.
    fn finish(self) -> FederationOutcome {
        let Gateway {
            cfg,
            insts,
            jobs,
            steals,
            batches,
            mut obs,
            ..
        } = self;
        if let Some(o) = obs.as_mut() {
            // Steal-hop distribution is a fleet-level fact, so the
            // gateway's registry owns it.
            for gj in &jobs {
                o.registry.steal_hops.observe(f64::from(gj.steals));
            }
        }
        let mut outcomes = Vec::with_capacity(insts.len());
        let mut inst_stats = Vec::with_capacity(insts.len());
        for (i, inst) in insts.into_iter().enumerate() {
            let final_time = inst.q.now();
            let out = inst.sim.finish(final_time, inst.events);
            inst_stats.push((
                i,
                inst.routed,
                inst.batches,
                inst.stolen_in,
                inst.stolen_out,
                inst.pending_peak,
                inst.events,
                final_time,
            ));
            outcomes.push(out);
        }
        let mut reports = Vec::with_capacity(jobs.len());
        let mut first_submit = f64::INFINITY;
        let mut last_cleanup: f64 = 0.0;
        let mut unfinished = 0usize;
        for gj in &jobs {
            let out = &outcomes[gj.owner];
            let meta = &out.jobs[gj.inst_job as usize];
            let (first, count) = (meta.first_task, meta.task_count as usize);
            let mut first_start = f64::INFINITY;
            let mut job_cleanup = f64::NAN;
            let mut completed = 0usize;
            let mut core_seconds = 0.0;
            for tid in first..first + count as u64 {
                let r = &out.records[tid as usize];
                if let Some(s) = r.start_t {
                    first_start = first_start.min(s);
                    if let Some(e) = r.end_t {
                        core_seconds += r.cores as f64 * (e - s).max(0.0);
                    }
                }
                if let Some(c) = r.cleanup_t {
                    completed += 1;
                    job_cleanup = if job_cleanup.is_nan() { c } else { job_cleanup.max(c) };
                }
            }
            unfinished += count - completed;
            first_submit = first_submit.min(gj.submit_t);
            if job_cleanup.is_finite() {
                last_cleanup = last_cleanup.max(job_cleanup);
            }
            reports.push(JobReport {
                class: gj.class,
                submit_t: gj.submit_t,
                latency: if first_start.is_finite() {
                    first_start - gj.submit_t
                } else {
                    f64::NAN
                },
                last_cleanup: job_cleanup,
                owner: gj.owner,
                steals: gj.steals,
                tasks: count,
                completed,
                core_seconds,
            });
        }
        let instances: Vec<InstanceReport> = inst_stats
            .into_iter()
            .map(
                |(i, routed, inst_batches, stolen_in, stolen_out, pending_peak, events, ft)| {
                    let lats: Vec<f64> = reports
                        .iter()
                        .filter(|j| j.owner == i)
                        .map(|j| j.latency)
                        .collect();
                    InstanceReport {
                        instance: i,
                        jobs: reports.iter().filter(|j| j.owner == i).count(),
                        routed,
                        batches: inst_batches,
                        stolen_in,
                        stolen_out,
                        pending_peak,
                        latency: LatencySummary::of(&lats),
                        events,
                        final_time: ft,
                    }
                },
            )
            .collect();
        let all_lats: Vec<f64> = reports.iter().map(|j| j.latency).collect();
        let final_time = outcomes.iter().map(|o| o.final_time).fold(0.0, f64::max);
        let span = if first_submit.is_finite() && last_cleanup > first_submit {
            last_cleanup - first_submit
        } else {
            0.0
        };
        // Merge the gateway recorder with every per-instance snapshot
        // into one fleet-wide, time-ordered view. `None` when nothing
        // in the fleet recorded.
        let gateway_snap = obs.map(|o| o.snapshot());
        let mut parts: Vec<&ObsSnapshot> = Vec::new();
        if let Some(s) = gateway_snap.as_ref() {
            parts.push(s);
        }
        parts.extend(outcomes.iter().filter_map(|o| o.obs.as_ref()));
        let obs = if parts.is_empty() {
            None
        } else {
            Some(ObsSnapshot::merge(parts))
        };
        FederationOutcome {
            config: cfg,
            latency: LatencySummary::of(&all_lats),
            jobs: reports,
            instances,
            steals,
            batches,
            final_time,
            span,
            unfinished,
            outcomes,
            obs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::placement::Strategy;
    use crate::scheduler::costmodel::CostModel;
    use crate::scheduler::noise::NoiseModel;
    use crate::workload::contention::ContentionMix;

    fn quiet_sim(nodes: u32, seed: u64) -> SchedulerSim {
        SchedulerSim::new(
            Cluster::tx_green(nodes),
            CostModel::slurm_like_tx_green(),
            NoiseModel::dedicated(),
            seed,
        )
        .with_placement(Strategy::NodeBased)
        .with_backfill(true)
    }

    fn fleet(cfg: FederationConfig, nodes_each: u32, seed: u64) -> Gateway {
        let sims = (0..cfg.instances)
            .map(|i| quiet_sim(nodes_each, seed.wrapping_add(i as u64)))
            .collect();
        Gateway::new(cfg, sims)
    }

    #[test]
    fn federated_tiny_mix_drains_and_conserves_jobs() {
        let mix = ContentionMix::preset("tiny", 8).unwrap();
        let subs = mix.generate(7);
        let n_jobs = subs.len();
        let cfg = FederationConfig {
            instances: 2,
            batch: 4,
            steal_threshold: 4,
            ..FederationConfig::default()
        };
        let out = fleet(cfg, 4, 7).run(subs);
        assert_eq!(out.jobs.len(), n_jobs, "every job accounted once");
        assert_eq!(out.unfinished, 0, "fleet drains completely");
        assert!(out.jobs.iter().all(|j| j.completed == j.tasks));
        assert!(out.jobs.iter().all(|j| j.latency.is_finite() && j.latency >= 0.0));
        let owned: usize = out.instances.iter().map(|r| r.jobs).sum();
        assert_eq!(owned, n_jobs, "ownership partitions the jobs");
        let routed: u64 = out.instances.iter().map(|r| r.routed).sum();
        assert_eq!(routed as usize, n_jobs);
        assert_eq!(
            out.instances.iter().map(|r| r.stolen_in).sum::<u64>(),
            out.instances.iter().map(|r| r.stolen_out).sum::<u64>(),
            "steals balance"
        );
        assert!(out.batches >= 1);
        assert!(out.latency.n == n_jobs);
        assert!(out.span > 0.0);
    }

    #[test]
    fn round_robin_breaks_least_backlog_ties() {
        // Simultaneous identical jobs on an idle fleet must spread
        // round-robin: every instance ends up owning some.
        let mix = ContentionMix::preset("tiny", 8).unwrap();
        let subs = mix.generate(11);
        let cfg = FederationConfig {
            instances: 4,
            batch: 1,
            steal_threshold: usize::MAX, // isolate routing from stealing
            ..FederationConfig::default()
        };
        let out = fleet(cfg, 2, 11).run(subs);
        assert_eq!(out.steals, 0, "threshold disables stealing");
        assert!(
            out.instances.iter().all(|r| r.routed > 0),
            "routing spreads across the fleet: {:?}",
            out.instances.iter().map(|r| r.routed).collect::<Vec<_>>()
        );
        assert_eq!(out.unfinished, 0);
    }

    #[test]
    fn determinism_same_seed_same_rollup() {
        let mix = ContentionMix::preset("tiny", 8).unwrap();
        let cfg = FederationConfig {
            instances: 2,
            batch: 2,
            steal_threshold: 2,
            ..FederationConfig::default()
        };
        let a = fleet(cfg, 4, 3).run(mix.generate(3));
        let b = fleet(cfg, 4, 3).run(mix.generate(3));
        assert_eq!(a.steals, b.steals);
        assert_eq!(a.batches, b.batches);
        assert_eq!(a.final_time, b.final_time);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.owner, y.owner);
            assert_eq!(x.latency.to_bits(), y.latency.to_bits());
        }
    }
}
