//! Fleet-wide rollup of one federated run: per-instance and aggregate
//! launch-latency quantiles, routing/steal counters, and the raw
//! per-instance [`SimOutcome`]s for anyone who needs the full records.

use crate::obs::ObsSnapshot;
use crate::scheduler::SimOutcome;
use crate::sim::Time;
use crate::util::stats;
use crate::workload::contention::JobClass;

use super::FederationConfig;

/// Launch-latency quantiles over one population (NaN when empty,
/// matching the report conventions elsewhere).
#[derive(Debug, Clone, Copy)]
pub struct LatencySummary {
    /// Samples (jobs that actually started).
    pub n: usize,
    pub median: f64,
    pub p95: f64,
    pub max: f64,
}

impl LatencySummary {
    /// Summarize a latency sample set; NaN entries (never-started jobs)
    /// are excluded from the quantiles but not from anything else.
    pub fn of(xs: &[f64]) -> LatencySummary {
        let clean: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
        LatencySummary {
            n: clean.len(),
            median: stats::median(&clean),
            p95: stats::percentile(&clean, 95.0),
            max: stats::max(&clean),
        }
    }
}

/// One gateway job, as seen end-to-end: where it finally ran and how
/// long the *user* waited (gateway submit → first task start on the
/// final owner — batching delay and steal hops included, exactly the
/// latency a client of the fleet observes).
#[derive(Debug, Clone)]
pub struct JobReport {
    pub class: JobClass,
    /// When the job hit the gateway (virtual time).
    pub submit_t: Time,
    /// Gateway submit → first task start on the final owner; NaN if no
    /// task ever started.
    pub latency: Time,
    /// Latest task cleanup, for span accounting (NaN if none finished).
    pub last_cleanup: Time,
    /// Final owning instance (after any steals).
    pub owner: usize,
    /// Times this job was stolen between instances.
    pub steals: u32,
    /// Scheduling tasks in the job.
    pub tasks: usize,
    /// Tasks that reached cleanup on the final owner.
    pub completed: usize,
    /// Delivered core-seconds on the final owner.
    pub core_seconds: f64,
}

/// Per-instance slice of the rollup.
#[derive(Debug, Clone)]
pub struct InstanceReport {
    pub instance: usize,
    /// Jobs this instance finally owned (post-steal).
    pub jobs: usize,
    /// Jobs initially routed here by the gateway.
    pub routed: u64,
    /// Batch flushes injected into this instance.
    pub batches: u64,
    pub stolen_in: u64,
    pub stolen_out: u64,
    /// Peak pending depth (queued tasks) observed at window boundaries.
    pub pending_peak: usize,
    /// Latency quantiles over the jobs this instance finally owned.
    pub latency: LatencySummary,
    /// DES events this instance processed across all lock-step windows.
    pub events: u64,
    /// The instance's final virtual clock.
    pub final_time: Time,
}

/// Everything measured from one federated run.
#[derive(Debug)]
pub struct FederationOutcome {
    /// The knobs the gateway ran with.
    pub config: FederationConfig,
    /// One report per gateway job, in gateway-arrival order.
    pub jobs: Vec<JobReport>,
    /// One report per instance, in instance order.
    pub instances: Vec<InstanceReport>,
    /// Aggregate launch-latency quantiles over all jobs.
    pub latency: LatencySummary,
    /// Jobs migrated between instances by the steal pass.
    pub steals: u64,
    /// Batch flushes across all instances.
    pub batches: u64,
    /// Latest final clock across the instances.
    pub final_time: Time,
    /// First gateway submit → last cleanup anywhere, seconds.
    pub span: Time,
    /// Tasks that never reached cleanup on their final owner (0 for a
    /// fully drained fleet).
    pub unfinished: usize,
    /// The raw per-instance outcomes (instance order), for consumers
    /// that need full records — e.g. the per-class contention rollup.
    pub outcomes: Vec<SimOutcome>,
    /// Fleet-wide flight-recorder snapshot: the gateway's own recorder
    /// merged with every per-instance one, time-ordered. `None` when
    /// nothing in the fleet recorded.
    pub obs: Option<ObsSnapshot>,
}

impl FederationOutcome {
    /// Latency quantiles restricted to one class.
    pub fn class_latency(&self, class: JobClass) -> LatencySummary {
        let xs: Vec<f64> = self
            .jobs
            .iter()
            .filter(|j| j.class == class)
            .map(|j| j.latency)
            .collect();
        LatencySummary::of(&xs)
    }

    /// Total delivered core-seconds across the fleet.
    pub fn core_seconds(&self) -> f64 {
        self.jobs.iter().map(|j| j.core_seconds).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary_skips_never_started_jobs() {
        let s = LatencySummary::of(&[1.0, 3.0, f64::NAN, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.max, 3.0);
        let empty = LatencySummary::of(&[f64::NAN]);
        assert_eq!(empty.n, 0);
        assert!(empty.median.is_nan() && empty.p95.is_nan() && empty.max.is_nan());
    }
}
