//! Scheduler federation: N independent [`SchedulerSim`] instances, each
//! owning a disjoint cluster partition, behind a submission [`Gateway`].
//!
//! The paper's core observation is that one centralized scheduler server
//! serializes registration, dispatch and cleanup — and collapses when a
//! large array of short tasks lands on it. Aggregation (the paper's
//! contribution) attacks the *per-job* cost; federation attacks the
//! *fleet* ceiling: once a single server saturates, the only way to
//! accept a higher submission rate is to run several schedulers side by
//! side and split the machine between them.
//!
//! The design here mirrors how sites actually deploy that idea:
//!
//! * **Disjoint partitions** — each instance owns its own cluster,
//!   placement index, pending queues and (optionally) rapid-launch pool
//!   fleet. Nothing is shared; an instance is exactly the single-
//!   scheduler simulation from [`crate::scheduler`].
//! * **Batched ingestion** — the gateway buffers incoming submissions
//!   per instance and injects them in batches (configurable size and
//!   flush cadence), the way a submit front-end amortizes RPC overhead.
//! * **Deterministic routing** — least-backlog (queued + buffered
//!   tasks) with a round-robin cursor breaking ties, so a quiet fleet
//!   degrades to pure round-robin and every run replays bit-for-bit.
//! * **Work stealing** — when a partition's pending depth exceeds the
//!   configured threshold, whole still-queued jobs are withdrawn
//!   through the preempt-safe requeue path
//!   ([`crate::scheduler::SchedulerSim::withdraw_job`]) and resubmitted
//!   to the shallowest instance, where they re-route through that
//!   instance's own shape router.
//!
//! Instances advance in **lock-step** on a shared virtual clock: the
//! gateway runs every instance strictly up to the next boundary
//! (submission arrival or flush tick) with
//! [`crate::sim::run_until_before`], injects that boundary's work, and
//! only then lets the instant play out. With one instance and batch
//! size 1 the gateway is a pass-through: the schedule is bit-for-bit
//! the direct [`SchedulerSim::run`] schedule (pinned by
//! `rust/tests/federation_properties.rs`).
//!
//! [`SchedulerSim`]: crate::scheduler::SchedulerSim
//! [`SchedulerSim::run`]: crate::scheduler::SchedulerSim::run

pub mod gateway;
pub mod outcome;

pub use gateway::Gateway;
pub use outcome::{FederationOutcome, InstanceReport, JobReport, LatencySummary};

use crate::sim::Time;

/// Federation knobs (the `federation = { … }` config table).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FederationConfig {
    /// Scheduler instances behind the gateway (each owns a disjoint
    /// partition).
    pub instances: usize,
    /// Submissions buffered per instance before an early flush (1 =
    /// inject every submission the instant it arrives).
    pub batch: usize,
    /// Flush/steal cadence, virtual seconds: every tick the gateway
    /// flushes all buffers and runs one steal pass.
    pub flush_interval: Time,
    /// Pending-depth (queued tasks) above which an instance becomes a
    /// steal donor.
    pub steal_threshold: usize,
}

impl Default for FederationConfig {
    fn default() -> Self {
        FederationConfig {
            instances: 4,
            batch: 8,
            flush_interval: 1.0,
            steal_threshold: 64,
        }
    }
}

impl FederationConfig {
    /// A pass-through gateway: one instance, no batching. The
    /// configuration under which the gateway must reproduce the direct
    /// scheduler bit-for-bit.
    pub fn passthrough() -> FederationConfig {
        FederationConfig {
            instances: 1,
            batch: 1,
            ..FederationConfig::default()
        }
    }

    /// Validate the knobs (mirrors the config layer's error style).
    pub fn validate(&self) -> Result<(), String> {
        if self.instances == 0 {
            return Err("federation.instances must be >= 1".into());
        }
        if self.batch == 0 {
            return Err("federation.batch must be >= 1".into());
        }
        if !(self.flush_interval > 0.0) {
            return Err("federation.flush_interval must be > 0".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_validates() {
        assert!(FederationConfig::default().validate().is_ok());
        assert!(FederationConfig::passthrough().validate().is_ok());
    }

    #[test]
    fn degenerate_knobs_are_rejected() {
        let mut c = FederationConfig::default();
        c.instances = 0;
        assert!(c.validate().is_err());
        let mut c = FederationConfig::default();
        c.batch = 0;
        assert!(c.validate().is_err());
        let mut c = FederationConfig::default();
        c.flush_interval = 0.0;
        assert!(c.validate().is_err());
    }
}
