//! # llsched — node-based job scheduling for large-scale short-running jobs
//!
//! Reproduction of Byun et al., *"Node-Based Job Scheduling for Large Scale
//! Simulations of Short Running Jobs"*, IEEE HPEC 2021.
//!
//! The library is organized in layers (see `DESIGN.md`):
//!
//! * **Substrates** — a deterministic discrete-event simulation engine
//!   ([`sim`]), a cluster model ([`cluster`]), a pluggable placement
//!   subsystem over an incremental free-capacity index ([`placement`]),
//!   an elastic rapid-launch node pool with node-based dispatch
//!   ([`pool`]), and a Slurm-like centralized scheduler ([`scheduler`])
//!   with a calibrated cost model.
//! * **The paper's contribution** — task-aggregation modes ([`aggregation`]):
//!   per-task (naive baseline), per-core multi-level scheduling
//!   (LLMapReduce MIMO), and per-node *node-based* scheduling ("triples
//!   mode") with generated per-node execution scripts and explicit
//!   process-affinity control. User-facing launch tools mirroring
//!   LLsub / LLMapReduce live in [`lltools`]; preemptable spot jobs in
//!   [`spot`].
//! * **Workloads & metrics** — the paper's Table I/II benchmark matrix
//!   ([`workload`]), utilization timelines, overhead metrics and
//!   paper-style reports ([`metrics`]), a fault-injection and
//!   churn layer ([`fault`]) with a deterministic audit log so failure
//!   scenarios replay bit-for-bit from a seed, and a scheduler flight
//!   recorder ([`obs`]) tracing individual dispatch decisions into
//!   Perfetto-loadable exports.
//! * **Real execution** — a PJRT runtime ([`runtime`]) that loads the
//!   AOT-compiled JAX/Pallas artifacts, and a pinned-thread executor
//!   ([`exec`]) so scheduled tasks can run *real* compute payloads.
//! * **Infrastructure** — config parsing ([`config`]), a bench harness
//!   ([`mod@bench`]), a tiny property-testing toolkit ([`testing`]) and
//!   utilities ([`util`]); all hand-rolled because this build is fully
//!   offline (no serde/clap/criterion/proptest in the vendored crate set).

pub mod aggregation;
pub mod bench;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod exec;
pub mod fault;
pub mod federation;
pub mod lltools;
pub mod metrics;
pub mod obs;
pub mod placement;
pub mod pool;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod spot;
pub mod testing;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
