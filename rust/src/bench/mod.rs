//! Benchmark harness (the vendored crate set has no criterion).
//!
//! Provides warmup + repeated measurement with summary statistics, wall
//!-clock budgets, and a uniform report format used by every bench binary
//! under `benches/`.

pub mod watchdog;

use crate::util::json::Json;
use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Configuration of one measurement.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    /// Warmup iterations (not recorded).
    pub warmup: usize,
    /// Recorded iterations.
    pub iters: usize,
    /// Soft wall-clock budget; measurement stops early (but after at
    /// least one recorded iteration) once exceeded.
    pub max_wall: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup: 1,
            iters: 10,
            max_wall: Duration::from_secs(60),
        }
    }
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall times, seconds.
    pub times: Vec<f64>,
    pub summary: Summary,
}

impl BenchResult {
    /// One-line report: `name  mean ± std  [min … p95]  (n)`.
    pub fn line(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>10} ± {:>8}  [{} … {}]  n={}",
            self.name,
            fmt_secs(s.mean),
            fmt_secs(s.stddev),
            fmt_secs(s.min),
            fmt_secs(s.p95),
            s.n
        )
    }
}

/// Format seconds with adaptive units.
pub fn fmt_secs(s: f64) -> String {
    if s.is_nan() {
        return "n/a".into();
    }
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Measure `f`, returning per-iteration times. `f` receives the iteration
/// index and must return something observable to defeat dead-code
/// elimination (return any value; it is black-boxed).
pub fn bench<T>(name: &str, opts: BenchOpts, mut f: impl FnMut(usize) -> T) -> BenchResult {
    for i in 0..opts.warmup {
        black_box(f(i));
    }
    let start = Instant::now();
    let mut times = Vec::with_capacity(opts.iters);
    for i in 0..opts.iters {
        let t0 = Instant::now();
        black_box(f(i));
        times.push(t0.elapsed().as_secs_f64());
        if start.elapsed() > opts.max_wall && !times.is_empty() {
            break;
        }
    }
    let summary = Summary::of(&times);
    BenchResult {
        name: name.to_string(),
        times,
        summary,
    }
}

/// Opaque value sink (stable `std::hint::black_box`).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Parse `--flag value` from argv (panics on malformed input: a bench
/// invocation error should fail loudly, not silently run the default).
pub fn arg_value(args: &[String], flag: &str) -> Option<f64> {
    args.iter().position(|a| a == flag).map(|i| {
        args.get(i + 1)
            .unwrap_or_else(|| panic!("{flag} needs a value"))
            .parse::<f64>()
            .unwrap_or_else(|_| panic!("{flag} needs a number"))
    })
}

/// Whether a bare `--flag` switch is present in argv.
pub fn has_flag(args: &[String], flag: &str) -> bool {
    args.iter().any(|a| a == flag)
}

/// One measurement as a `BENCH_*.json` artifact row (times in seconds).
pub fn result_row(section: &str, r: &BenchResult) -> Json {
    Json::obj()
        .set("section", section)
        .set("name", r.name.as_str())
        .set("mean_s", r.summary.mean)
        .set("p50_s", r.summary.p50)
        .set("iters", r.summary.n)
}

/// Write a `BENCH_*.json` report at the crate root — the uniform bench
/// artifact pattern (`bench`, `command`, result sections, `passed`).
/// Failure to write is a warning, not an error: the measurements on
/// stdout are the primary output.
pub fn write_artifact(path: &str, report: &Json) {
    if let Err(e) = std::fs::write(path, report.to_pretty()) {
        eprintln!("warning: could not write {path}: {e}");
    } else {
        println!("\nwrote {path}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_requested_iterations() {
        let r = bench(
            "noop",
            BenchOpts { warmup: 2, iters: 5, max_wall: Duration::from_secs(10) },
            |i| i * 2,
        );
        assert_eq!(r.times.len(), 5);
        assert_eq!(r.summary.n, 5);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn wall_budget_stops_early() {
        let r = bench(
            "sleepy",
            BenchOpts { warmup: 0, iters: 100, max_wall: Duration::from_millis(30) },
            |_| std::thread::sleep(Duration::from_millis(20)),
        );
        assert!(r.times.len() < 100, "stopped after {} iters", r.times.len());
        assert!(!r.times.is_empty());
    }

    #[test]
    fn line_formats() {
        let r = bench("fmt", BenchOpts::default(), |_| 1 + 1);
        let line = r.line();
        assert!(line.contains("fmt"));
        assert!(line.contains("n="));
    }

    #[test]
    fn argv_helpers_parse() {
        let args: Vec<String> =
            ["--max-nodes", "64", "--quick"].iter().map(|s| s.to_string()).collect();
        assert_eq!(arg_value(&args, "--max-nodes"), Some(64.0));
        assert_eq!(arg_value(&args, "--runs"), None);
        assert!(has_flag(&args, "--quick"));
        assert!(!has_flag(&args, "--verbose"));
    }

    #[test]
    fn result_row_carries_summary() {
        let r = bench("rowed", BenchOpts::default(), |_| 1 + 1);
        let row = result_row("sec", &r).to_pretty();
        assert!(row.contains("\"section\": \"sec\""));
        assert!(row.contains("\"name\": \"rowed\""));
        assert!(row.contains("\"p50_s\":"));
    }

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(2.5), "2.500s");
        assert!(fmt_secs(0.002).ends_with("ms"));
        assert!(fmt_secs(2e-6).ends_with("µs"));
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert_eq!(fmt_secs(f64::NAN), "n/a");
    }
}
