//! SLO watchdog over the bench trajectory: compare fresh `BENCH_*.json`
//! artifacts against pinned baselines and fail on regression of the
//! headline metrics the repo advertises.
//!
//! The watched metrics are deliberately *ratios* (speedups, rate
//! gains), not absolute wall times: ratios compare the same machine
//! against itself inside one bench run, so a baseline recorded on one
//! box remains meaningful on another. Each metric also carries a hard
//! floor from the repo's performance claims (≥10× pool dispatch at
//! ≥4096 nodes, ≥5× trace replay at ≥65536 nodes, ≥3× federation rate
//! gain) — a fresh value below its floor fails even when it matches
//! the baseline, because then the *claim* is broken, not just the
//! trend.

use crate::util::json::Json;
use std::path::Path;

/// Artifacts the watchdog knows how to read headline metrics from.
pub const WATCHED: [&str; 2] = ["BENCH_pool.json", "BENCH_federation.json"];

/// One headline metric extracted from a bench artifact.
#[derive(Debug, Clone)]
pub struct Metric {
    /// Stable metric name, e.g. `dispatch_speedup_at_4096_nodes`.
    pub name: &'static str,
    /// Hard floor from the repo's performance claims.
    pub floor: f64,
    pub value: f64,
}

/// The comparison of one metric between a fresh artifact and the
/// pinned baseline.
#[derive(Debug, Clone)]
pub struct Verdict {
    /// `file:metric` label.
    pub metric: String,
    pub fresh: f64,
    /// NaN when the baseline artifact or metric was missing.
    pub baseline: f64,
    pub floor: f64,
    pub passed: bool,
    pub note: String,
}

/// The full watchdog outcome over every watched artifact.
#[derive(Debug, Clone)]
pub struct WatchdogReport {
    pub verdicts: Vec<Verdict>,
    pub passed: bool,
}

impl WatchdogReport {
    /// One report line per verdict plus a PASS/FAIL trailer.
    pub fn lines(&self) -> Vec<String> {
        let mut out: Vec<String> = self
            .verdicts
            .iter()
            .map(|v| {
                format!(
                    "{:<4} {:<50} fresh {:>9} baseline {:>9} floor {:>6} — {}",
                    if v.passed { "ok" } else { "FAIL" },
                    v.metric,
                    num(v.fresh),
                    num(v.baseline),
                    num(v.floor),
                    v.note,
                )
            })
            .collect();
        out.push(format!("watchdog: {}", if self.passed { "PASS" } else { "FAIL" }));
        out
    }

    /// The report as a `BENCH_obs.json` section (NaN emits as null).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .verdicts
            .iter()
            .map(|v| {
                Json::obj()
                    .set("metric", v.metric.clone())
                    .set("fresh", v.fresh)
                    .set("baseline", v.baseline)
                    .set("floor", v.floor)
                    .set("passed", v.passed)
                    .set("note", v.note.clone())
            })
            .collect();
        Json::obj().set("verdicts", Json::Arr(rows)).set("passed", self.passed)
    }
}

/// Extract the headline metrics this artifact carries. Unknown files
/// and absent sections yield an empty list, never an error — artifact
/// schemas may grow fields without breaking the watchdog.
pub fn headline_metrics(file: &str, doc: &Json) -> Vec<Metric> {
    let mut ms = Vec::new();
    match file {
        "BENCH_pool.json" => {
            if let Some(v) = max_speedup(doc, "dispatch", 4096.0) {
                ms.push(Metric { name: "dispatch_speedup_at_4096_nodes", floor: 10.0, value: v });
            }
            if let Some(v) = max_speedup(doc, "trace", 65536.0) {
                ms.push(Metric { name: "trace_speedup_at_65536_nodes", floor: 5.0, value: v });
            }
        }
        "BENCH_federation.json" => {
            if let Some(v) = doc.get("rate_gain").and_then(Json::as_f64) {
                ms.push(Metric { name: "federation_rate_gain", floor: 3.0, value: v });
            }
        }
        _ => {}
    }
    ms
}

/// Best `speedup` among `section` rows at or past the scale cutoff.
fn max_speedup(doc: &Json, section: &str, min_nodes: f64) -> Option<f64> {
    let rows = doc.get(section)?.as_arr()?;
    let mut best: Option<f64> = None;
    for row in rows {
        let nodes = row.get("nodes").and_then(Json::as_f64).unwrap_or(0.0);
        if nodes < min_nodes {
            continue;
        }
        if let Some(s) = row.get("speedup").and_then(Json::as_f64) {
            best = Some(best.map_or(s, |b| b.max(s)));
        }
    }
    best
}

/// Compare fresh artifacts in `fresh_dir` against pinned baselines in
/// `baseline_dir`. `tolerance` is fractional (0.25 = a fresh ratio may
/// sit up to 25% below its baseline before counting as a regression —
/// the watched ratios are machine-independent but still jitter). An
/// unreadable fresh artifact fails loudly; a missing *baseline* only
/// skips the comparison (first runs have nothing pinned yet), while
/// the hard floors still apply.
pub fn run(fresh_dir: &Path, baseline_dir: &Path, tolerance: f64) -> WatchdogReport {
    let mut verdicts = Vec::new();
    let mut passed = true;
    for file in WATCHED {
        let fresh_doc = match load(&fresh_dir.join(file)) {
            Ok(d) => d,
            Err(e) => {
                passed = false;
                verdicts.push(Verdict {
                    metric: file.to_string(),
                    fresh: f64::NAN,
                    baseline: f64::NAN,
                    floor: f64::NAN,
                    passed: false,
                    note: format!("fresh artifact unreadable: {e}"),
                });
                continue;
            }
        };
        let base_metrics = match load(&baseline_dir.join(file)) {
            Ok(d) => headline_metrics(file, &d),
            Err(_) => Vec::new(),
        };
        for m in headline_metrics(file, &fresh_doc) {
            let baseline = base_metrics.iter().find(|b| b.name == m.name).map(|b| b.value);
            let mut ok = true;
            let mut notes: Vec<String> = Vec::new();
            if m.value < m.floor {
                ok = false;
                notes.push(format!("below the {:.0}x floor", m.floor));
            }
            match baseline {
                Some(b) if m.value < b * (1.0 - tolerance) => {
                    ok = false;
                    notes.push(format!(
                        "regressed more than {:.0}% vs baseline",
                        tolerance * 100.0
                    ));
                }
                Some(_) => {}
                None => notes.push("no baseline; comparison skipped".into()),
            }
            if notes.is_empty() {
                notes.push("ok".into());
            }
            passed &= ok;
            verdicts.push(Verdict {
                metric: format!("{file}:{}", m.name),
                fresh: m.value,
                baseline: baseline.unwrap_or(f64::NAN),
                floor: m.floor,
                passed: ok,
                note: notes.join("; "),
            });
        }
    }
    WatchdogReport { verdicts, passed }
}

fn load(path: &Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

fn num(x: f64) -> String {
    if x.is_nan() {
        "-".into()
    } else {
        format!("{x:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn pool_doc(dispatch_4096: f64, trace_65536: f64) -> String {
        Json::obj()
            .set("bench", "bench_pool")
            .set(
                "dispatch",
                Json::Arr(vec![
                    Json::obj().set("nodes", 512u64).set("speedup", 84.2),
                    Json::obj().set("nodes", 4096u64).set("speedup", dispatch_4096),
                ]),
            )
            .set(
                "trace",
                Json::Arr(vec![
                    Json::obj().set("nodes", 4096u64).set("speedup", 2.7),
                    Json::obj().set("nodes", 65536u64).set("speedup", trace_65536),
                ]),
            )
            .to_pretty()
    }

    #[test]
    fn headline_extraction_picks_the_at_scale_rows() {
        let doc = Json::parse(&pool_doc(174.6, 28.9)).unwrap();
        let ms = headline_metrics("BENCH_pool.json", &doc);
        assert_eq!(ms.len(), 2);
        assert_eq!(ms[0].name, "dispatch_speedup_at_4096_nodes");
        assert_eq!(ms[0].value, 174.6, "the 512-node row is below the cutoff");
        assert_eq!(ms[1].value, 28.9);
        let fed = Json::obj().set("rate_gain", 4.0);
        let ms = headline_metrics("BENCH_federation.json", &fed);
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].value, 4.0);
        assert!(headline_metrics("BENCH_other.json", &fed).is_empty());
    }

    #[test]
    fn watchdog_passes_matching_dirs_and_fails_regressions() {
        let root = std::env::temp_dir().join("llsched_watchdog_regression_test");
        let (fresh, base) = (root.join("fresh"), root.join("base"));
        fs::create_dir_all(&fresh).unwrap();
        fs::create_dir_all(&base).unwrap();
        let fed = Json::obj().set("rate_gain", 4.0).to_pretty();
        fs::write(base.join("BENCH_pool.json"), pool_doc(174.6, 28.9)).unwrap();
        fs::write(base.join("BENCH_federation.json"), &fed).unwrap();
        fs::write(fresh.join("BENCH_pool.json"), pool_doc(174.6, 28.9)).unwrap();
        fs::write(fresh.join("BENCH_federation.json"), &fed).unwrap();
        let rep = run(&fresh, &base, 0.25);
        assert!(rep.passed, "{:?}", rep.lines());
        assert_eq!(rep.verdicts.len(), 3);
        // A drop past the tolerance band fails (100 < 174.6 * 0.75)...
        fs::write(fresh.join("BENCH_pool.json"), pool_doc(100.0, 28.9)).unwrap();
        assert!(!run(&fresh, &base, 0.25).passed);
        // ...a drop inside it does not (140 > 174.6 * 0.75).
        fs::write(fresh.join("BENCH_pool.json"), pool_doc(140.0, 28.9)).unwrap();
        assert!(run(&fresh, &base, 0.25).passed);
        // Breaking the hard floor fails even with a matching baseline.
        fs::write(fresh.join("BENCH_pool.json"), pool_doc(8.0, 28.9)).unwrap();
        fs::write(base.join("BENCH_pool.json"), pool_doc(8.0, 28.9)).unwrap();
        assert!(!run(&fresh, &base, 0.25).passed);
    }

    #[test]
    fn missing_baseline_skips_while_missing_fresh_fails() {
        let root = std::env::temp_dir().join("llsched_watchdog_missing_test");
        let (fresh, base) = (root.join("fresh"), root.join("base"));
        fs::create_dir_all(&fresh).unwrap();
        fs::create_dir_all(&base).unwrap();
        fs::write(fresh.join("BENCH_pool.json"), pool_doc(174.6, 28.9)).unwrap();
        let fed = Json::obj().set("rate_gain", 4.0).to_pretty();
        fs::write(fresh.join("BENCH_federation.json"), &fed).unwrap();
        let rep = run(&fresh, &base, 0.25);
        assert!(rep.passed, "no baseline is a skip: {:?}", rep.lines());
        assert!(rep.verdicts.iter().all(|v| v.baseline.is_nan()));
        // An unreadable fresh artifact is loud, not a silent pass.
        fs::remove_file(fresh.join("BENCH_federation.json")).unwrap();
        let rep = run(&fresh, &base, 0.25);
        assert!(!rep.passed);
        // The JSON view mirrors the verdicts.
        let j = rep.to_json();
        assert_eq!(j.get("passed"), Some(&Json::Bool(false)));
        assert_eq!(j.get("verdicts").and_then(Json::as_arr).map(<[Json]>::len), Some(3));
    }
}
