//! Preemptable spot jobs and node-based release (paper §I).
//!
//! "Fast launch requires available resources, but automatic preemption can
//! be slow to terminate low-priority spot jobs… The node-based scheduling
//! approach can also be applied to preemptable spot jobs, allocating the
//! compute resources for a given spot job by nodes instead of compute
//! cores. Node based scheduling enables faster release of spot jobs and
//! reduces the workloads on the scheduler."
//!
//! This module builds spot jobs in either allocation style and measures
//! the *release latency*: the time from the preemption request until all
//! of the spot job's resources are free again (every scheduling task
//! signalled + cleaned up). Core-based spot jobs need `P` signal + cleanup
//! transactions; node-based need `N` — the same 64× event reduction the
//! headline benchmark shows.

use crate::aggregation::plan::{Aggregator, ClusterShape, Workload};
use crate::aggregation::{MultiLevel, NodeBased};
use crate::cluster::Cluster;
use crate::config::Mode;
use crate::error::Result;
use crate::scheduler::costmodel::CostModel;
use crate::scheduler::core::{SchedulerSim, TaskModel};
use crate::scheduler::job::JobSpec;
use crate::scheduler::noise::NoiseModel;
use crate::sim::{EventQueue, Time};

/// Spot-job priority (below every normal job).
pub const SPOT_PRIORITY: i32 = -100;

/// Build a spot job that soaks `nodes` nodes with long-running filler
/// work, aggregated per-core or per-node.
pub fn spot_job(mode: Mode, nodes: u32, cores_per_node: u32, run_seconds: f64) -> Result<JobSpec> {
    let shape = ClusterShape {
        nodes,
        cores_per_node,
        task_mem_mib: 256,
    };
    let w = Workload::Uniform {
        count: shape.processors(),
        duration: run_seconds,
    };
    let mut job = match mode {
        Mode::NodeBased => NodeBased::default().plan("spot:triples", &w, &shape)?,
        _ => MultiLevel.plan("spot:mimo", &w, &shape)?,
    };
    job.priority = SPOT_PRIORITY;
    job.preemptable = true;
    Ok(job)
}

/// Result of one preemption experiment.
#[derive(Debug, Clone, Copy)]
pub struct ReleaseOutcome {
    /// When preemption was requested.
    pub preempt_t: Time,
    /// When the last spot resource was released (last cleanup).
    pub released_t: Time,
    /// Release latency (the paper's figure of merit for spot jobs).
    pub release_latency: Time,
    /// Scheduling tasks that had to be signalled + cleaned.
    pub sched_tasks: u64,
}

/// Run the spot-release experiment: fill `nodes` with a spot job, let it
/// run, request preemption at `preempt_at`, measure the release latency.
pub fn measure_release(
    mode: Mode,
    nodes: u32,
    cores_per_node: u32,
    preempt_at: Time,
    seed: u64,
) -> Result<ReleaseOutcome> {
    let cluster = Cluster::homogeneous(nodes, cores_per_node, 192 * 1024);
    let mut sim = SchedulerSim::new(
        cluster,
        CostModel::slurm_like_tx_green(),
        NoiseModel::dedicated(),
        seed,
    )
    .with_task_model(TaskModel {
        startup: 0.0,
        jitter_sigma: 0.0,
        p_node_late: 0.0,
        late_range: (0.0, 0.0),
    });
    let mut q = EventQueue::new();
    // Spot job wants to run far longer than the preemption point.
    let spec = spot_job(mode, nodes, cores_per_node, preempt_at * 100.0)?;
    let job = sim.submit_at(&mut q, 0.0, spec);
    sim.preempt_at(&mut q, preempt_at, job);
    let out = sim.run(&mut q);
    let released_t = out
        .records
        .iter()
        .filter(|r| r.job == job)
        .map(|r| r.cleanup_t.expect("spot job fully cleaned"))
        .fold(0.0, f64::max);
    let sched_tasks = out.records.iter().filter(|r| r.job == job).count() as u64;
    Ok(ReleaseOutcome {
        preempt_t: preempt_at,
        released_t,
        release_latency: released_t - preempt_at,
        sched_tasks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spot_jobs_are_low_priority_and_preemptable() {
        let j = spot_job(Mode::NodeBased, 4, 64, 1000.0).unwrap();
        assert_eq!(j.priority, SPOT_PRIORITY);
        assert!(j.preemptable);
        assert_eq!(j.array_size(), 4);
        let j2 = spot_job(Mode::MultiLevel, 4, 64, 1000.0).unwrap();
        assert_eq!(j2.array_size(), 256);
    }

    #[test]
    fn node_based_release_is_much_faster() {
        let core = measure_release(Mode::MultiLevel, 8, 64, 50.0, 1).unwrap();
        let node = measure_release(Mode::NodeBased, 8, 64, 50.0, 1).unwrap();
        assert_eq!(core.sched_tasks, 512);
        assert_eq!(node.sched_tasks, 8);
        assert!(node.release_latency > 0.0);
        assert!(
            node.release_latency * 10.0 < core.release_latency,
            "node {} vs core {}",
            node.release_latency,
            core.release_latency
        );
    }

    #[test]
    fn release_latency_scales_with_sched_tasks() {
        let small = measure_release(Mode::MultiLevel, 2, 64, 20.0, 3).unwrap();
        let big = measure_release(Mode::MultiLevel, 8, 64, 20.0, 3).unwrap();
        assert!(big.release_latency > 2.0 * small.release_latency);
    }
}
