//! Deterministic discrete-event simulation (DES) engine.
//!
//! The paper's experiments run on up to 512 nodes × 64 cores with up to
//! ~8 million compute tasks; we reproduce them in *virtual time* on one
//! machine. The engine is a classic event-calendar design: a binary heap
//! of `(time, seq)`-ordered events with a strictly monotone clock and a
//! stable FIFO tie-break for simultaneous events, so every run is exactly
//! reproducible.
//!
//! The scheduler ([`crate::scheduler`]) is written as an [`Actor`] over its
//! own event enum; unit tests in this module exercise the engine with toy
//! actors.

mod calendar;
pub mod engine;
pub mod event;

pub use engine::{run, run_until, run_until_before, Actor};
pub use event::{EventQueue, QueueBackend, Scheduled, WakeToken};

/// Virtual time, in seconds. `f64` gives microsecond resolution over the
/// multi-hour horizons the paper measures, with cheap arithmetic.
pub type Time = f64;

/// Epsilon used when two events must be ordered but occur "at the same
/// instant" conceptually (e.g. RPC turnaround); keeps traces readable.
pub const TICK: Time = 1e-6;
