//! Event calendar: a time-ordered priority queue with stable FIFO
//! tie-breaking, cancellable wake tokens, and a pluggable backend.
//!
//! Two backends share one façade, selected at construction:
//!
//! * [`QueueBackend::Binary`] — the classic binary heap (default);
//! * [`QueueBackend::Calendar`] — a Brown-style calendar queue
//!   ([`super::calendar`]), bucketed by time for O(1)-amortized holds
//!   on dense event sets.
//!
//! Wake tokens ([`WakeToken`]) are cancellable/reschedulable timer
//! handles. Cancellation is *lazy*: the entry stays in the backend but
//! its generation-checked slab slot ([`crate::util::slab::Slab`]) is
//! retired, and both [`EventQueue::pop`] and [`EventQueue::peek_time`]
//! skip such stale entries. A token held after its event fired (or was
//! cancelled) is a stale generation — every later `cancel` on it is a
//! detected no-op, never a hit on an unrelated reused slot.

use super::calendar::CalendarQueue;
use super::Time;
use crate::util::slab::{Slab, SlabKey};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at a virtual time. Ordering is `(time, seq)` so
/// same-time events pop in insertion order (determinism).
#[derive(Debug)]
pub struct Scheduled<E> {
    pub time: Time,
    pub seq: u64,
    pub event: E,
    /// Wake-token slot, when scheduled through [`EventQueue::at_token`].
    pub(super) token: Option<SlabKey>,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert to get earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN event time")
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Which priority-queue implementation backs the calendar.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueBackend {
    /// `std::collections::BinaryHeap` — O(log n) push/pop, the seed
    /// implementation and the reference for equivalence tests.
    #[default]
    Binary,
    /// Bucketed calendar queue — events hash into time buckets of
    /// adaptive width, amortizing pops toward O(1) on dense calendars.
    Calendar,
}

/// A cancellable/reschedulable handle to one scheduled event.
///
/// Obtained from [`EventQueue::at_token`] / [`EventQueue::after_token`].
/// The handle is `Copy`; staleness (fired, cancelled, or rescheduled)
/// is detected through the slab generation, so holding — or dropping —
/// an outdated token is always safe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WakeToken(SlabKey);

#[derive(Debug)]
enum Store<E> {
    Binary(BinaryHeap<Scheduled<E>>),
    Calendar(CalendarQueue<E>),
}

impl<E> Store<E> {
    fn push(&mut self, entry: Scheduled<E>) {
        match self {
            Store::Binary(h) => h.push(entry),
            Store::Calendar(c) => c.push(entry),
        }
    }

    fn pop_min(&mut self) -> Option<Scheduled<E>> {
        match self {
            Store::Binary(h) => h.pop(),
            Store::Calendar(c) => c.pop_min(),
        }
    }

    /// `(time, token)` of the earliest entry, stale or not.
    fn peek_min(&self) -> Option<(Time, Option<SlabKey>)> {
        match self {
            Store::Binary(h) => h.peek().map(|e| (e.time, e.token)),
            Store::Calendar(c) => c.peek_min(),
        }
    }
}

/// The event calendar.
#[derive(Debug)]
pub struct EventQueue<E> {
    store: Store<E>,
    seq: u64,
    now: Time,
    scheduled_total: u64,
    /// Entries that are still due to fire (excludes lazily-cancelled
    /// wake entries that still sit in the backend).
    live: usize,
    /// Generation-checked wake slots; an entry whose key is no longer
    /// in the slab is stale and gets skipped on pop/peek.
    tokens: Slab<()>,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Empty calendar at time 0, on the default (binary-heap) backend.
    pub fn new() -> Self {
        Self::with_backend(QueueBackend::Binary)
    }

    /// Empty calendar at time 0 on an explicit backend.
    pub fn with_backend(backend: QueueBackend) -> Self {
        EventQueue {
            store: match backend {
                QueueBackend::Binary => Store::Binary(BinaryHeap::new()),
                QueueBackend::Calendar => Store::Calendar(CalendarQueue::new()),
            },
            seq: 0,
            now: 0.0,
            scheduled_total: 0,
            live: 0,
            tokens: Slab::new(),
        }
    }

    /// Which backend this calendar runs on.
    pub fn backend(&self) -> QueueBackend {
        match self.store {
            Store::Binary(_) => QueueBackend::Binary,
            Store::Calendar(_) => QueueBackend::Calendar,
        }
    }

    /// Current virtual time (the time of the last popped event).
    pub fn now(&self) -> Time {
        self.now
    }

    fn push_entry(&mut self, at: Time, event: E, token: Option<SlabKey>) {
        // Times in the past are clamped to `now` (the event fires
        // "immediately"), which keeps actor code free of time
        // bookkeeping bugs.
        let t = if at < self.now { self.now } else { at };
        self.seq += 1;
        self.scheduled_total += 1;
        self.live += 1;
        self.store.push(Scheduled {
            time: t,
            seq: self.seq,
            event,
            token,
        });
    }

    /// Schedule `event` at absolute time `at`.
    pub fn at(&mut self, at: Time, event: E) {
        self.push_entry(at, event, None);
    }

    /// Schedule `event` after a relative delay.
    pub fn after(&mut self, delay: Time, event: E) {
        debug_assert!(delay >= 0.0, "negative delay");
        self.at(self.now + delay, event);
    }

    /// Schedule `event` at absolute time `at` and return a cancellable
    /// handle to it.
    pub fn at_token(&mut self, at: Time, event: E) -> WakeToken {
        let key = self.tokens.insert(());
        self.push_entry(at, event, Some(key));
        WakeToken(key)
    }

    /// Schedule `event` after a relative delay, with a cancellable
    /// handle.
    pub fn after_token(&mut self, delay: Time, event: E) -> WakeToken {
        debug_assert!(delay >= 0.0, "negative delay");
        self.at_token(self.now + delay, event)
    }

    /// Cancel the event behind `tok`. Returns `true` if it was still
    /// pending; `false` if it already fired, was cancelled, or was
    /// rescheduled (stale generation — a detected no-op). The backend
    /// entry is dropped lazily on the next pop/peek that reaches it.
    pub fn cancel(&mut self, tok: WakeToken) -> bool {
        if self.tokens.remove(tok.0).is_some() {
            self.live -= 1;
            true
        } else {
            false
        }
    }

    /// Move a pending wake to a new time (earlier or later), returning
    /// the replacement handle. If `tok` already fired or was cancelled,
    /// this degenerates to a fresh [`Self::at_token`].
    pub fn reschedule(&mut self, tok: WakeToken, at: Time, event: E) -> WakeToken {
        self.cancel(tok);
        self.at_token(at, event)
    }

    /// Whether the event behind `tok` is still pending.
    pub fn token_pending(&self, tok: WakeToken) -> bool {
        self.tokens.contains(tok.0)
    }

    /// Pop the earliest live event, advancing the clock to its
    /// timestamp. Lazily discards cancelled wake entries on the way.
    pub fn pop(&mut self) -> Option<Scheduled<E>> {
        self.pop_if_until(f64::INFINITY)
    }

    /// The peek/pop coalescing fast path: pop the earliest live event
    /// only if it is due at or before `horizon`. One call replaces the
    /// `peek_time` + bound check + `pop` triple in the engine loop, and
    /// stale-entry skipping happens exactly once, here.
    pub fn pop_if_until(&mut self, horizon: Time) -> Option<Scheduled<E>> {
        loop {
            let (time, token) = self.store.peek_min()?;
            if let Some(key) = token {
                if !self.tokens.contains(key) {
                    // Lazily-cancelled wake: drop and keep looking.
                    let _ = self.store.pop_min();
                    continue;
                }
            }
            if time > horizon {
                return None;
            }
            let ev = self.store.pop_min().expect("peeked entry vanished");
            if let Some(key) = ev.token {
                // Retire the slot: the token has fired, so any handle
                // still held for it goes stale now.
                self.tokens.remove(key);
            }
            self.live -= 1;
            debug_assert!(ev.time >= self.now, "time went backwards");
            self.now = ev.time;
            return Some(ev);
        }
    }

    /// Same-timestamp coalescing: pop the next live event only if it is
    /// scheduled at exactly `t`. Lets a handler drain the whole run of
    /// simultaneous events it is part of without bouncing through the
    /// engine loop.
    pub fn pop_if_at(&mut self, t: Time) -> Option<Scheduled<E>> {
        match self.peek_time() {
            Some(pt) if pt == t => self.pop_if_until(t),
            _ => None,
        }
    }

    /// Peek the next live event time without popping it. Takes `&mut`
    /// because cancelled wake entries encountered at the front are
    /// discarded here (otherwise a cancelled timer would fence the
    /// horizon check in `run_until`).
    pub fn peek_time(&mut self) -> Option<Time> {
        loop {
            let (time, token) = self.store.peek_min()?;
            if let Some(key) = token {
                if !self.tokens.contains(key) {
                    let _ = self.store.pop_min();
                    continue;
                }
            }
            return Some(time);
        }
    }

    /// Number of pending live events (cancelled wakes excluded).
    pub fn len(&self) -> usize {
        self.live
    }

    /// True if no live events are pending.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Total events ever scheduled (engine throughput accounting;
    /// includes later-cancelled wakes).
    pub fn scheduled_total(&self) -> u64 {
        self.scheduled_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.at(3.0, "c");
        q.at(1.0, "a");
        q.at(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn same_time_is_fifo() {
        for backend in [QueueBackend::Binary, QueueBackend::Calendar] {
            let mut q = EventQueue::with_backend(backend);
            for i in 0..100 {
                q.at(5.0, i);
            }
            let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
            assert_eq!(order, (0..100).collect::<Vec<_>>(), "{backend:?}");
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.at(2.0, ());
        q.at(1.0, ());
        q.pop();
        assert_eq!(q.now(), 1.0);
        q.pop();
        assert_eq!(q.now(), 2.0);
    }

    #[test]
    fn past_times_clamp_to_now() {
        let mut q = EventQueue::new();
        q.at(5.0, "later");
        q.pop();
        q.at(1.0, "past"); // scheduled at t=1 while now=5 → fires at 5
        let e = q.pop().unwrap();
        assert_eq!(e.time, 5.0);
        assert_eq!(e.event, "past");
    }

    #[test]
    fn after_is_relative() {
        let mut q = EventQueue::new();
        q.at(10.0, "first");
        q.pop();
        q.after(2.5, "second");
        assert_eq!(q.pop().unwrap().time, 12.5);
    }

    #[test]
    fn counters() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        q.at(1.0, ());
        q.at(2.0, ());
        assert_eq!(q.len(), 2);
        assert_eq!(q.scheduled_total(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
        assert_eq!(q.scheduled_total(), 2);
    }

    #[test]
    fn wake_cancel_before_fire() {
        let mut q = EventQueue::new();
        q.at(1.0, "keep");
        let tok = q.at_token(2.0, "cancelled");
        q.at(3.0, "also-keep");
        assert!(q.token_pending(tok));
        assert!(q.cancel(tok), "first cancel hits");
        assert!(!q.token_pending(tok));
        assert!(!q.cancel(tok), "second cancel is a detected no-op");
        assert_eq!(q.len(), 2);
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
        assert_eq!(order, vec!["keep", "also-keep"]);
        assert_eq!(q.now(), 3.0, "cancelled wake never advanced the clock");
    }

    #[test]
    fn wake_reschedule_moves_earlier() {
        let mut q = EventQueue::new();
        let tok = q.at_token(10.0, "wake");
        q.at(5.0, "mid");
        let tok = q.reschedule(tok, 3.0, "wake");
        assert!(q.token_pending(tok));
        assert_eq!(q.len(), 2, "old entry is dead, not counted");
        let e = q.pop().unwrap();
        assert_eq!((e.time, e.event), (3.0, "wake"));
        assert!(!q.token_pending(tok), "fired token goes stale");
        let e = q.pop().unwrap();
        assert_eq!((e.time, e.event), (5.0, "mid"));
        assert!(q.pop().is_none(), "the original t=10 entry was skipped");
    }

    #[test]
    fn wake_reschedule_moves_later() {
        let mut q = EventQueue::new();
        let tok = q.at_token(2.0, "wake");
        q.at(5.0, "mid");
        let tok = q.reschedule(tok, 8.0, "wake");
        assert_eq!(q.peek_time(), Some(5.0), "stale front entry pruned by peek");
        let order: Vec<(Time, &str)> =
            std::iter::from_fn(|| q.pop().map(|e| (e.time, e.event))).collect();
        assert_eq!(order, vec![(5.0, "mid"), (8.0, "wake")]);
        assert!(!q.token_pending(tok));
    }

    #[test]
    fn wake_fire_then_stale_handle_is_ignored() {
        // "Fire after owner drop": the owner lost interest but never
        // cancelled; the token fires normally, and the retained handle
        // is stale from then on — even after the slot is recycled.
        let mut q = EventQueue::new();
        let old = q.at_token(1.0, 1u32);
        assert_eq!(q.pop().unwrap().event, 1);
        assert!(!q.cancel(old), "fired token cannot be cancelled");
        // The freed slot is recycled for a new token at a new generation.
        let newer = q.at_token(2.0, 2u32);
        assert!(!q.cancel(old), "stale generation never cancels the new wake");
        assert!(q.token_pending(newer));
        assert_eq!(q.pop().unwrap().event, 2);
    }

    #[test]
    fn cancelled_wake_at_front_does_not_fence_peek() {
        let mut q = EventQueue::new();
        let tok = q.at_token(1.0, "wake");
        q.at(4.0, "real");
        q.cancel(tok);
        // peek must see through the dead entry, or run_until would stop
        // at a horizon the dead entry straddles.
        assert_eq!(q.peek_time(), Some(4.0));
        assert_eq!(q.pop().unwrap().event, "real");
    }

    #[test]
    fn pop_if_until_respects_horizon() {
        let mut q = EventQueue::new();
        q.at(1.0, "a");
        q.at(2.0, "b");
        q.at(3.0, "c");
        assert_eq!(q.pop_if_until(2.0).unwrap().event, "a");
        assert_eq!(q.pop_if_until(2.0).unwrap().event, "b");
        assert!(q.pop_if_until(2.0).is_none(), "c is past the horizon");
        assert_eq!(q.len(), 1);
        assert_eq!(q.now(), 2.0);
    }

    #[test]
    fn pop_if_at_drains_simultaneous_runs_only() {
        let mut q = EventQueue::new();
        q.at(1.0, "x1");
        q.at(1.0, "x2");
        q.at(2.0, "y");
        let first = q.pop().unwrap();
        assert_eq!(first.event, "x1");
        // Coalesce the rest of the t=1 run.
        assert_eq!(q.pop_if_at(first.time).unwrap().event, "x2");
        assert!(q.pop_if_at(first.time).is_none(), "t=2 is a new instant");
        assert_eq!(q.pop().unwrap().event, "y");
    }

    #[test]
    fn calendar_backend_matches_binary_on_random_workload() {
        // Deterministic LCG; interleaved pushes/pops, including wakes
        // cancelled on both queues identically.
        let mut seed: u64 = 0x9E3779B97F4A7C15;
        let mut rand = move || {
            seed = seed
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            (seed >> 33) as f64 / (1u64 << 31) as f64
        };
        let mut bin = EventQueue::with_backend(QueueBackend::Binary);
        let mut cal = EventQueue::with_backend(QueueBackend::Calendar);
        let mut toks: Vec<(WakeToken, WakeToken)> = Vec::new();
        for i in 0..5000u32 {
            let r = rand();
            if r < 0.55 {
                let t = bin.now() + rand() * 50.0;
                if rand() < 0.25 {
                    toks.push((bin.at_token(t, i), cal.at_token(t, i)));
                } else {
                    bin.at(t, i);
                    cal.at(t, i);
                }
            } else if r < 0.65 && !toks.is_empty() {
                let (tb, tc) = toks.swap_remove((rand() * toks.len() as f64) as usize);
                assert_eq!(bin.cancel(tb), cal.cancel(tc));
            } else {
                let (a, b) = (bin.pop(), cal.pop());
                match (a, b) {
                    (None, None) => {}
                    (Some(x), Some(y)) => {
                        assert_eq!(x.time, y.time);
                        assert_eq!(x.event, y.event);
                    }
                    (x, y) => panic!("backend divergence: {x:?} vs {y:?}"),
                }
            }
            assert_eq!(bin.len(), cal.len());
        }
        loop {
            match (bin.pop(), cal.pop()) {
                (None, None) => break,
                (Some(x), Some(y)) => {
                    assert_eq!((x.time, x.event), (y.time, y.event));
                }
                (x, y) => panic!("drain divergence: {x:?} vs {y:?}"),
            }
        }
    }
}
