//! Event calendar: a time-ordered priority queue with stable FIFO
//! tie-breaking for events scheduled at the same virtual instant.

use super::Time;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at a virtual time. Ordering is `(time, seq)` so
/// same-time events pop in insertion order (determinism).
#[derive(Debug)]
pub struct Scheduled<E> {
    pub time: Time,
    pub seq: u64,
    pub event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert to get earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN event time")
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The event calendar.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: Time,
    scheduled_total: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Empty calendar at time 0.
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
            scheduled_total: 0,
        }
    }

    /// Current virtual time (the time of the last popped event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `event` at absolute time `at`. Times in the past are
    /// clamped to `now` (the event fires "immediately"), which keeps actor
    /// code free of time bookkeeping bugs.
    pub fn at(&mut self, at: Time, event: E) {
        let t = if at < self.now { self.now } else { at };
        self.seq += 1;
        self.scheduled_total += 1;
        self.heap.push(Scheduled {
            time: t,
            seq: self.seq,
            event,
        });
    }

    /// Schedule `event` after a relative delay.
    pub fn after(&mut self, delay: Time, event: E) {
        debug_assert!(delay >= 0.0, "negative delay");
        self.at(self.now + delay, event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<Scheduled<E>> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.time >= self.now, "time went backwards");
        self.now = ev.time;
        Some(ev)
    }

    /// Peek the next event time without popping.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.time)
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True if no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events ever scheduled (engine throughput accounting).
    pub fn scheduled_total(&self) -> u64 {
        self.scheduled_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.at(3.0, "c");
        q.at(1.0, "a");
        q.at(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn same_time_is_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.at(5.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.at(2.0, ());
        q.at(1.0, ());
        q.pop();
        assert_eq!(q.now(), 1.0);
        q.pop();
        assert_eq!(q.now(), 2.0);
    }

    #[test]
    fn past_times_clamp_to_now() {
        let mut q = EventQueue::new();
        q.at(5.0, "later");
        q.pop();
        q.at(1.0, "past"); // scheduled at t=1 while now=5 → fires at 5
        let e = q.pop().unwrap();
        assert_eq!(e.time, 5.0);
        assert_eq!(e.event, "past");
    }

    #[test]
    fn after_is_relative() {
        let mut q = EventQueue::new();
        q.at(10.0, "first");
        q.pop();
        q.after(2.5, "second");
        assert_eq!(q.pop().unwrap().time, 12.5);
    }

    #[test]
    fn counters() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        q.at(1.0, ());
        q.at(2.0, ());
        assert_eq!(q.len(), 2);
        assert_eq!(q.scheduled_total(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
        assert_eq!(q.scheduled_total(), 2);
    }
}
