//! Brown-style calendar queue: the alternative [`super::EventQueue`]
//! backend for dense event sets.
//!
//! Events hash into `nbuckets` buckets of `width` seconds by
//! `floor(t / width) mod nbuckets`; a "year" is one sweep of all
//! buckets (`nbuckets × width` seconds). Pop scans forward from the
//! bucket of the last popped event, taking the earliest `(time, seq)`
//! entry that belongs to the bucket's *current* year; when a whole year
//! is empty (a sparse calendar), it falls back to a direct global
//! minimum scan. On resize the queue rebuilds with the bucket count
//! sized to the live population and the width sized to the live time
//! span, keeping expected bucket occupancy (and therefore expected pop
//! cost) constant.
//!
//! Determinism: ordering is the total order `(time, seq)` — exactly the
//! binary heap's — so both backends replay identical schedules; the
//! equivalence tests in [`super::event`] and `rust/tests/` pin this.

use super::event::Scheduled;
use super::Time;
use crate::util::slab::SlabKey;

const MIN_BUCKETS: usize = 64;
const MAX_BUCKETS: usize = 1 << 20;
const MIN_WIDTH: Time = 1e-9;

#[derive(Debug)]
pub(super) struct CalendarQueue<E> {
    buckets: Vec<Vec<Scheduled<E>>>,
    /// Always a power of two (cheap modulo is not assumed; correctness
    /// only needs consistency between push and pop).
    nbuckets: usize,
    width: Time,
    /// Global serial (`floor(t / width)`) of the last popped event's
    /// bucket-year; pops resume scanning here.
    cur_serial: u64,
    len: usize,
}

impl<E> CalendarQueue<E> {
    pub(super) fn new() -> Self {
        CalendarQueue {
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            nbuckets: MIN_BUCKETS,
            width: 1.0,
            cur_serial: 0,
            len: 0,
        }
    }

    #[inline]
    fn serial(&self, t: Time) -> u64 {
        (t / self.width) as u64
    }

    /// Strict `(time, seq)` order — the FIFO-stable total order.
    #[inline]
    fn before(a: &Scheduled<E>, b: &Scheduled<E>) -> bool {
        a.time < b.time || (a.time == b.time && a.seq < b.seq)
    }

    pub(super) fn push(&mut self, entry: Scheduled<E>) {
        if self.len + 1 > 4 * self.nbuckets && self.nbuckets < MAX_BUCKETS {
            self.rebuild();
        }
        let s = self.serial(entry.time);
        // Defensive: a push earlier than the scan cursor (cannot happen
        // through EventQueue, which clamps to `now`) must rewind the
        // cursor or the entry would only be found by the sparse
        // fallback.
        if s < self.cur_serial {
            self.cur_serial = s;
        }
        let b = (s % self.nbuckets as u64) as usize;
        self.buckets[b].push(entry);
        self.len += 1;
    }

    pub(super) fn pop_min(&mut self) -> Option<Scheduled<E>> {
        let (b, i) = self.find_min()?;
        let entry = self.buckets[b].swap_remove(i);
        self.cur_serial = self.serial(entry.time);
        self.len -= 1;
        if self.len < self.nbuckets / 8 && self.nbuckets > MIN_BUCKETS {
            self.rebuild();
        }
        Some(entry)
    }

    pub(super) fn peek_min(&self) -> Option<(Time, Option<SlabKey>)> {
        let (b, i) = self.find_min()?;
        let e = &self.buckets[b][i];
        Some((e.time, e.token))
    }

    /// Locate the minimum entry: year-scan from the cursor, then the
    /// sparse global fallback. Returns `(bucket, index)`.
    fn find_min(&self) -> Option<(usize, usize)> {
        if self.len == 0 {
            return None;
        }
        let nb = self.nbuckets as u64;
        for s in self.cur_serial..self.cur_serial + nb {
            let b = (s % nb) as usize;
            if self.buckets[b].is_empty() {
                continue;
            }
            let mut best: Option<usize> = None;
            for (i, e) in self.buckets[b].iter().enumerate() {
                // Entries from future years share the bucket; only this
                // year's entries are candidates.
                if self.serial(e.time) != s {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(j) => Self::before(e, &self.buckets[b][j]),
                };
                if better {
                    best = Some(i);
                }
            }
            if let Some(i) = best {
                return Some((b, i));
            }
        }
        // Sparse calendar: nothing within a full year of the cursor.
        let mut pos: Option<(usize, usize)> = None;
        for (b, bucket) in self.buckets.iter().enumerate() {
            for (i, e) in bucket.iter().enumerate() {
                let better = match pos {
                    None => true,
                    Some((pb, pi)) => Self::before(e, &self.buckets[pb][pi]),
                };
                if better {
                    pos = Some((b, i));
                }
            }
        }
        pos
    }

    /// Resize to the live population: `nbuckets ≈ len/2` (so ~2 entries
    /// per bucket) and `width = span/nbuckets` (so the live span is one
    /// year and the year-scan never walks far).
    fn rebuild(&mut self) {
        let old = std::mem::take(&mut self.buckets);
        let all: Vec<Scheduled<E>> = old.into_iter().flatten().collect();
        self.nbuckets = (all.len() / 2).next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        let (mut min_t, mut max_t) = (f64::INFINITY, f64::NEG_INFINITY);
        for e in &all {
            min_t = min_t.min(e.time);
            max_t = max_t.max(e.time);
        }
        let span = (max_t - min_t).max(0.0);
        self.width = (span / self.nbuckets as f64).max(MIN_WIDTH);
        self.buckets = (0..self.nbuckets).map(|_| Vec::new()).collect();
        self.cur_serial = if all.is_empty() { 0 } else { self.serial(min_t) };
        self.len = 0;
        for e in all {
            let s = self.serial(e.time);
            let b = (s % self.nbuckets as u64) as usize;
            self.buckets[b].push(e);
            self.len += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(time: Time, seq: u64) -> Scheduled<u64> {
        Scheduled { time, seq, event: seq, token: None }
    }

    #[test]
    fn pops_in_total_order_across_rebuilds() {
        let mut c = CalendarQueue::new();
        // Push enough to force several grow rebuilds, in shuffled order.
        let n = 3000u64;
        for i in 0..n {
            let t = ((i * 7919) % n) as f64 * 0.01;
            c.push(entry(t, i));
        }
        let mut last = (f64::NEG_INFINITY, 0u64);
        let mut popped = 0;
        while let Some(e) = c.pop_min() {
            assert!(
                e.time > last.0 || (e.time == last.0 && e.seq > last.1),
                "order violated: {:?} after {:?}",
                (e.time, e.seq),
                last
            );
            last = (e.time, e.seq);
            popped += 1;
        }
        assert_eq!(popped, n);
    }

    #[test]
    fn sparse_fallback_finds_far_future_events() {
        let mut c = CalendarQueue::new();
        c.push(entry(0.5, 1));
        c.push(entry(1e6, 2)); // far outside the initial 64-second year
        assert_eq!(c.pop_min().unwrap().seq, 1);
        assert_eq!(c.peek_min().unwrap().0, 1e6);
        assert_eq!(c.pop_min().unwrap().seq, 2);
        assert!(c.pop_min().is_none());
    }

    #[test]
    fn same_instant_is_seq_ordered() {
        let mut c = CalendarQueue::new();
        for i in (0..50u64).rev() {
            c.push(entry(7.25, i));
        }
        let order: Vec<u64> = std::iter::from_fn(|| c.pop_min().map(|e| e.seq)).collect();
        let mut expect: Vec<u64> = (0..50).collect();
        expect.sort_unstable();
        assert_eq!(order, expect);
    }

    #[test]
    fn interleaved_push_pop_keeps_cursor_consistent() {
        let mut c = CalendarQueue::new();
        c.push(entry(10.0, 1));
        assert_eq!(c.pop_min().unwrap().seq, 1);
        // New work at the same instant as the last pop (EventQueue
        // clamps to now): must be found even though the cursor already
        // sits in that serial.
        c.push(entry(10.0, 2));
        c.push(entry(10.1, 3));
        assert_eq!(c.pop_min().unwrap().seq, 2);
        assert_eq!(c.pop_min().unwrap().seq, 3);
    }
}
