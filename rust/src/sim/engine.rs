//! The event loop: drives an [`Actor`] over an [`EventQueue`] until the
//! calendar drains or a time horizon is reached.

use super::event::EventQueue;
use super::Time;

/// A simulation actor: owns all model state and reacts to its own events,
/// scheduling follow-ups on the queue.
pub trait Actor {
    /// The actor's event alphabet.
    type Event;

    /// Handle one event at virtual time `now`.
    fn handle(&mut self, now: Time, event: Self::Event, q: &mut EventQueue<Self::Event>);
}

/// Run until the calendar is empty. Returns `(final_time, events_processed)`.
pub fn run<A: Actor>(actor: &mut A, q: &mut EventQueue<A::Event>) -> (Time, u64) {
    run_until(actor, q, f64::INFINITY)
}

/// Run until the calendar is empty or the next event is past `horizon`.
/// Events at exactly `horizon` are processed.
pub fn run_until<A: Actor>(
    actor: &mut A,
    q: &mut EventQueue<A::Event>,
    horizon: Time,
) -> (Time, u64) {
    let mut processed: u64 = 0;
    // `pop_if_until` coalesces the peek + horizon check + pop triple
    // into one queue operation (and skips lazily-cancelled wakes).
    while let Some(ev) = q.pop_if_until(horizon) {
        actor.handle(ev.time, ev.event, q);
        processed += 1;
    }
    (q.now(), processed)
}

/// Run until the calendar is empty or the next event is at or past
/// `horizon`. Unlike [`run_until`], events at exactly `horizon` are
/// *not* processed — the caller owns the boundary instant. The
/// federation gateway leans on this: each instance advances to just
/// before a batch boundary, the gateway injects that boundary's
/// submissions, and only then does the instant play out — so injected
/// events take the low FIFO sequence numbers at the boundary exactly as
/// if they had been submitted up front.
pub fn run_until_before<A: Actor>(
    actor: &mut A,
    q: &mut EventQueue<A::Event>,
    horizon: Time,
) -> (Time, u64) {
    let mut processed: u64 = 0;
    while let Some(t) = q.peek_time() {
        if t >= horizon {
            break;
        }
        let ev = q.pop().expect("peeked event is live");
        actor.handle(ev.time, ev.event, q);
        processed += 1;
    }
    (q.now(), processed)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy actor: a ping-pong counter that reschedules itself `limit` times.
    struct PingPong {
        count: u32,
        limit: u32,
        times: Vec<Time>,
    }

    impl Actor for PingPong {
        type Event = ();

        fn handle(&mut self, now: Time, _ev: (), q: &mut EventQueue<()>) {
            self.count += 1;
            self.times.push(now);
            if self.count < self.limit {
                q.after(1.5, ());
            }
        }
    }

    #[test]
    fn self_rescheduling_actor_runs_to_completion() {
        let mut a = PingPong {
            count: 0,
            limit: 5,
            times: vec![],
        };
        let mut q = EventQueue::new();
        q.at(0.0, ());
        let (t, n) = run(&mut a, &mut q);
        assert_eq!(n, 5);
        assert_eq!(a.times, vec![0.0, 1.5, 3.0, 4.5, 6.0]);
        assert_eq!(t, 6.0);
    }

    #[test]
    fn horizon_stops_early_inclusive() {
        let mut a = PingPong {
            count: 0,
            limit: 100,
            times: vec![],
        };
        let mut q = EventQueue::new();
        q.at(0.0, ());
        let (_, n) = run_until(&mut a, &mut q, 3.0);
        // events at 0.0, 1.5, 3.0 processed; 4.5 not.
        assert_eq!(n, 3);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn strict_horizon_excludes_the_boundary_instant() {
        let mut a = PingPong {
            count: 0,
            limit: 100,
            times: vec![],
        };
        let mut q = EventQueue::new();
        q.at(0.0, ());
        let (_, n) = run_until_before(&mut a, &mut q, 3.0);
        // events at 0.0 and 1.5 processed; the one at 3.0 stays queued.
        assert_eq!(n, 2);
        assert_eq!(q.len(), 1);
        let (_, m) = run_until(&mut a, &mut q, 3.0);
        assert_eq!(m, 1, "the boundary event survives for an inclusive run");
    }

    /// M/D/1-style sanity check: Poisson-ish arrivals into a fixed-rate
    /// server; verify conservation (all arrivals eventually depart).
    enum QueueEv {
        Arrive(u32),
        Depart,
    }

    struct Server {
        // VecDeque, not Vec: `remove(0)` on a Vec is O(n) per departure
        // and the idiom tends to leak from test actors into real ones.
        waiting: std::collections::VecDeque<u32>,
        busy: bool,
        served: Vec<u32>,
        service_time: Time,
    }

    impl Actor for Server {
        type Event = QueueEv;

        fn handle(&mut self, _now: Time, ev: QueueEv, q: &mut EventQueue<QueueEv>) {
            match ev {
                QueueEv::Arrive(id) => {
                    self.waiting.push_back(id);
                    if !self.busy {
                        self.busy = true;
                        q.after(self.service_time, QueueEv::Depart);
                    }
                }
                QueueEv::Depart => {
                    let id = self.waiting.pop_front().expect("depart without waiter");
                    self.served.push(id);
                    if self.waiting.is_empty() {
                        self.busy = false;
                    } else {
                        q.after(self.service_time, QueueEv::Depart);
                    }
                }
            }
        }
    }

    #[test]
    fn queueing_conservation() {
        let mut s = Server {
            waiting: std::collections::VecDeque::new(),
            busy: false,
            served: vec![],
            service_time: 1.0,
        };
        let mut q = EventQueue::new();
        for i in 0..50u32 {
            q.at(0.1 * i as f64, QueueEv::Arrive(i));
        }
        let (t, _) = run(&mut s, &mut q);
        assert_eq!(s.served.len(), 50);
        assert_eq!(s.served, (0..50).collect::<Vec<_>>(), "FIFO order");
        // 50 jobs of 1s each at a single server; first arrival at 0.
        assert!((t - 50.0).abs() < 1e-9, "drain time {t}");
    }
}
