//! Compute-task payloads for the real executor.
//!
//! The paper's benchmark tasks are constant-time occupiers; the real
//! executor supports those (sleep / busy-spin) plus the genuine article:
//! a short-running simulation implemented by the AOT-compiled JAX/Pallas
//! artifact executed through PJRT.

use crate::error::Result;
use crate::runtime::server::RuntimeServer;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// What one compute task does.
#[derive(Clone)]
pub enum Payload {
    /// Sleep for the given seconds (a cooperative constant-time task).
    Sleep(f64),
    /// Busy-spin for the given seconds (an uncooperative one).
    Spin(f64),
    /// Run `iters` chained simulation steps through the node-local PJRT
    /// runtime server.
    Simulate { server: Arc<RuntimeServer>, iters: usize },
}

impl std::fmt::Debug for Payload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Payload::Sleep(s) => write!(f, "Sleep({s}s)"),
            Payload::Spin(s) => write!(f, "Spin({s}s)"),
            Payload::Simulate { iters, server } => write!(
                f,
                "Simulate({iters} iters of {})",
                server.artifact().name
            ),
        }
    }
}

/// Result of executing one compute task.
#[derive(Debug, Clone, Copy)]
pub struct TaskResult {
    /// Wall time the task took, seconds.
    pub wall: f64,
    /// Payload checksum (0 for sleep/spin) — integrity check for the
    /// simulate path, verified against the Python oracle in tests.
    pub checksum: f32,
}

impl Payload {
    /// Execute the payload for compute task `task_id`.
    pub fn run(&self, task_id: u64) -> Result<TaskResult> {
        let t0 = Instant::now();
        match self {
            Payload::Sleep(s) => {
                std::thread::sleep(Duration::from_secs_f64(*s));
                Ok(TaskResult { wall: t0.elapsed().as_secs_f64(), checksum: 0.0 })
            }
            Payload::Spin(s) => {
                let mut acc = task_id;
                while t0.elapsed().as_secs_f64() < *s {
                    // A little integer churn so the loop can't be elided.
                    for _ in 0..1000 {
                        acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
                    }
                    std::hint::black_box(acc);
                }
                Ok(TaskResult { wall: t0.elapsed().as_secs_f64(), checksum: 0.0 })
            }
            Payload::Simulate { server, iters } => {
                let checksum = server.run_task(task_id, *iters)?;
                Ok(TaskResult { wall: t0.elapsed().as_secs_f64(), checksum })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sleep_takes_about_right() {
        let r = Payload::Sleep(0.05).run(0).unwrap();
        assert!(r.wall >= 0.05 && r.wall < 0.5, "wall {}", r.wall);
        assert_eq!(r.checksum, 0.0);
    }

    #[test]
    fn spin_takes_about_right() {
        let r = Payload::Spin(0.05).run(1).unwrap();
        assert!(r.wall >= 0.05 && r.wall < 0.5, "wall {}", r.wall);
    }

    #[test]
    fn debug_formats() {
        assert_eq!(format!("{:?}", Payload::Sleep(1.0)), "Sleep(1s)");
        assert!(format!("{:?}", Payload::Spin(2.0)).contains("Spin"));
    }
    // Simulate-path tests live in rust/tests/runtime_integration.rs.
}
