//! Node executor: run one node script's lanes as pinned worker threads.
//!
//! This is the real-machine analogue of what the generated shell script
//! does on a TX-Green node: one worker per core, pinned with
//! `sched_setaffinity`, consuming its contiguous task range in a loop.
//! On a small dev box the pinning degrades gracefully (out-of-range cores
//! leave affinity untouched, see [`crate::cluster::affinity`]).

use crate::aggregation::script::NodeScript;
use crate::cluster::affinity::CoreMask;
use crate::error::{Error, Result};
use crate::exec::payload::Payload;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Outcome of running one node script.
#[derive(Debug, Clone)]
pub struct NodeRunReport {
    /// Total wall time for the node task, seconds.
    pub wall: f64,
    /// Compute tasks executed.
    pub tasks_run: u64,
    /// Tasks that returned an error.
    pub tasks_failed: u64,
    /// Sum of per-task wall times (serial work actually done).
    pub busy_seconds: f64,
    /// XOR-folded payload checksums (integrity fingerprint).
    pub checksum_fold: u32,
    /// Lanes that executed at least one task.
    pub active_lanes: usize,
}

impl NodeRunReport {
    /// Parallel efficiency: busy time / (wall × active lanes).
    pub fn efficiency(&self) -> f64 {
        if self.wall <= 0.0 || self.active_lanes == 0 {
            return 0.0;
        }
        self.busy_seconds / (self.wall * self.active_lanes as f64)
    }
}

/// Executes node scripts with real threads.
#[derive(Debug, Default)]
pub struct NodeExecutor {
    /// Apply core pinning (disable for tests on constrained hosts).
    pub pin: bool,
}

impl NodeExecutor {
    pub fn pinned() -> NodeExecutor {
        NodeExecutor { pin: true }
    }

    /// Run every lane of `script`, each lane a thread looping over its
    /// task range and invoking `payload` per task.
    pub fn run(&self, script: &NodeScript, payload: &Payload) -> Result<NodeRunReport> {
        let t0 = Instant::now();
        let failed = AtomicU64::new(0);
        let busy_us = AtomicU64::new(0);
        let checksum = AtomicU64::new(0);
        let active_lanes = script.lanes.iter().filter(|l| l.count() > 0).count();

        // std::thread::scope joins all lanes on exit and propagates lane
        // panics as a panic of the scope itself; catch it so a wedged
        // payload surfaces as an Err, not a test-killing unwind.
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            std::thread::scope(|scope| {
                for lane in script.lanes.iter().filter(|l| l.count() > 0) {
                    let payload = payload.clone();
                    let failed = &failed;
                    let busy_us = &busy_us;
                    let checksum = &checksum;
                    let pin = self.pin;
                    scope.spawn(move || {
                        if pin {
                            let mut mask = CoreMask::empty(lane.core + 1);
                            mask.set(lane.core);
                            // Best effort: out-of-range masks are no-ops.
                            let _ = mask.apply_to_current_thread();
                        }
                        for task_id in lane.start..lane.end {
                            match payload.run(task_id) {
                                Ok(r) => {
                                    busy_us.fetch_add((r.wall * 1e6) as u64, Ordering::Relaxed);
                                    checksum.fetch_xor(
                                        r.checksum.to_bits() as u64,
                                        Ordering::Relaxed,
                                    );
                                }
                                Err(_) => {
                                    failed.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                    });
                }
            })
        }))
        .map_err(|_| Error::Runtime("worker lane panicked".into()))?;

        Ok(NodeRunReport {
            wall: t0.elapsed().as_secs_f64(),
            tasks_run: script.total_tasks(),
            tasks_failed: failed.load(Ordering::Relaxed),
            busy_seconds: busy_us.load(Ordering::Relaxed) as f64 / 1e6,
            checksum_fold: checksum.load(Ordering::Relaxed) as u32,
            active_lanes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::script::build_scripts;

    #[test]
    fn runs_all_tasks_across_lanes() {
        // 4 lanes × 3 tasks of 10 ms.
        let scripts = build_scripts(12, 1, 4, 1);
        let rep = NodeExecutor::default()
            .run(&scripts[0], &Payload::Sleep(0.01))
            .unwrap();
        assert_eq!(rep.tasks_run, 12);
        assert_eq!(rep.tasks_failed, 0);
        assert_eq!(rep.active_lanes, 4);
        assert!(rep.busy_seconds >= 0.12 * 0.9, "busy {}", rep.busy_seconds);
        // Lanes run concurrently: wall ≈ 3 tasks, not 12.
        assert!(rep.wall < 0.12, "wall {}", rep.wall);
    }

    #[test]
    fn efficiency_reasonable_for_sleep_tasks() {
        let scripts = build_scripts(8, 1, 2, 1);
        let rep = NodeExecutor::default()
            .run(&scripts[0], &Payload::Sleep(0.02))
            .unwrap();
        let e = rep.efficiency();
        assert!(e > 0.5 && e <= 1.3, "efficiency {e}");
    }

    #[test]
    fn pinned_mode_smoke() {
        let scripts = build_scripts(2, 1, 2, 1);
        let rep = NodeExecutor::pinned()
            .run(&scripts[0], &Payload::Sleep(0.005))
            .unwrap();
        assert_eq!(rep.tasks_failed, 0);
        assert_eq!(rep.tasks_run, 2);
    }

    #[test]
    fn empty_lanes_are_skipped() {
        // 2 tasks on a 64-lane script: 62 empty lanes.
        let scripts = build_scripts(2, 1, 64, 1);
        let rep = NodeExecutor::default()
            .run(&scripts[0], &Payload::Sleep(0.001))
            .unwrap();
        assert_eq!(rep.active_lanes, 2);
        assert_eq!(rep.tasks_run, 2);
    }
}
