//! The real executor: run an aggregated job's compute tasks as actual
//! work on this machine's cores, following the generated node scripts'
//! structure (one pinned worker lane per core) — proving the aggregation
//! plans drive real execution, not just the DES.

pub mod payload;
pub mod worker;

pub use payload::Payload;
pub use worker::{NodeExecutor, NodeRunReport};
