//! A compute node: cores, memory, and a lifecycle state machine.

use crate::cluster::affinity::CoreMask;
use crate::error::{Error, Result};

/// Node identifier (dense index into the cluster's node table).
pub type NodeId = u32;

/// Node lifecycle states, mirroring what a Slurm-like scheduler tracks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Healthy and accepting work.
    Up,
    /// Running but not accepting new allocations (admin or preemption).
    Draining,
    /// Out of service. The paper hit a wedged node state in one 256-node
    /// medium-task run (the 2464 s outlier in Table III); failure-injection
    /// tests use this state to reproduce that incident.
    Down,
}

/// A compute node.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    /// Physical cores (64 on the paper's Xeon Phi 7210 nodes).
    pub cores: u32,
    /// Memory in MiB (192 GiB on the paper's nodes).
    pub mem_mib: u64,
    state: NodeState,
    /// Which cores are currently allocated.
    busy: CoreMask,
    /// Memory currently allocated, MiB.
    mem_used_mib: u64,
}

impl Node {
    /// A fresh idle node.
    pub fn new(id: NodeId, cores: u32, mem_mib: u64) -> Node {
        Node {
            id,
            cores,
            mem_mib,
            state: NodeState::Up,
            busy: CoreMask::empty(cores),
            mem_used_mib: 0,
        }
    }

    pub fn state(&self) -> NodeState {
        self.state
    }

    /// Administrative state change; allocation state is preserved so a
    /// draining node finishes its work.
    pub fn set_state(&mut self, s: NodeState) {
        self.state = s;
    }

    /// Number of free cores.
    pub fn free_cores(&self) -> u32 {
        self.cores - self.busy.count()
    }

    /// Number of allocated cores.
    pub fn busy_cores(&self) -> u32 {
        self.busy.count()
    }

    /// True if nothing is allocated.
    pub fn is_idle(&self) -> bool {
        self.busy.count() == 0 && self.mem_used_mib == 0
    }

    /// Free memory in MiB.
    pub fn free_mem_mib(&self) -> u64 {
        self.mem_mib - self.mem_used_mib
    }

    /// True if the node can accept a new allocation of this size.
    pub fn can_fit(&self, cores: u32, mem_mib: u64) -> bool {
        self.state == NodeState::Up && self.free_cores() >= cores && self.free_mem_mib() >= mem_mib
    }

    /// Allocate `cores` specific cores (lowest-index-first policy — the
    /// deterministic pinning order the generated node scripts use) plus
    /// memory. Returns the allocated mask.
    pub fn allocate(&mut self, cores: u32, mem_mib: u64) -> Result<CoreMask> {
        if !self.can_fit(cores, mem_mib) {
            return Err(Error::Infeasible(format!(
                "node {}: want {} cores/{} MiB, free {} cores/{} MiB, state {:?}",
                self.id,
                cores,
                mem_mib,
                self.free_cores(),
                self.free_mem_mib(),
                self.state
            )));
        }
        let mask = self.busy.take_lowest_free(cores);
        debug_assert_eq!(mask.count(), cores);
        self.mem_used_mib += mem_mib;
        Ok(mask)
    }

    /// Allocate the *whole* node (node-based scheduling path).
    pub fn allocate_whole(&mut self) -> Result<CoreMask> {
        let cores = self.cores;
        if !self.can_fit(cores, 0) {
            return Err(Error::Infeasible(format!(
                "node {} not wholly free ({} busy)",
                self.id,
                self.busy_cores()
            )));
        }
        let mem = self.free_mem_mib();
        self.allocate(cores, mem)
    }

    /// Release a previously allocated mask + memory.
    pub fn release(&mut self, mask: &CoreMask, mem_mib: u64) -> Result<()> {
        if !self.busy.contains(mask) {
            return Err(Error::InvalidTransition(format!(
                "node {}: releasing cores that are not allocated",
                self.id
            )));
        }
        if mem_mib > self.mem_used_mib {
            return Err(Error::InvalidTransition(format!(
                "node {}: releasing {} MiB but only {} allocated",
                self.id, mem_mib, self.mem_used_mib
            )));
        }
        self.busy.clear(mask);
        self.mem_used_mib -= mem_mib;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node() -> Node {
        Node::new(0, 64, 192 * 1024)
    }

    #[test]
    fn fresh_node_is_idle() {
        let n = node();
        assert!(n.is_idle());
        assert_eq!(n.free_cores(), 64);
        assert_eq!(n.free_mem_mib(), 192 * 1024);
        assert_eq!(n.state(), NodeState::Up);
    }

    #[test]
    fn allocate_then_release_roundtrip() {
        let mut n = node();
        let m = n.allocate(16, 1024).unwrap();
        assert_eq!(m.count(), 16);
        assert_eq!(n.free_cores(), 48);
        assert_eq!(n.free_mem_mib(), 192 * 1024 - 1024);
        n.release(&m, 1024).unwrap();
        assert!(n.is_idle());
    }

    #[test]
    fn allocation_is_lowest_first() {
        let mut n = node();
        let m = n.allocate(4, 0).unwrap();
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        let m2 = n.allocate(2, 0).unwrap();
        assert_eq!(m2.iter().collect::<Vec<_>>(), vec![4, 5]);
    }

    #[test]
    fn over_allocation_rejected() {
        let mut n = node();
        n.allocate(60, 0).unwrap();
        assert!(n.allocate(5, 0).is_err());
        assert!(n.allocate(4, 0).is_ok());
    }

    #[test]
    fn memory_limits_enforced() {
        let mut n = node();
        assert!(n.allocate(1, 192 * 1024 + 1).is_err());
        n.allocate(1, 192 * 1024).unwrap();
        assert!(n.allocate(1, 1).is_err());
    }

    #[test]
    fn down_node_rejects_work() {
        let mut n = node();
        n.set_state(NodeState::Down);
        assert!(!n.can_fit(1, 0));
        assert!(n.allocate(1, 0).is_err());
    }

    #[test]
    fn whole_node_allocation() {
        let mut n = node();
        let m = n.allocate_whole().unwrap();
        assert_eq!(m.count(), 64);
        assert_eq!(n.free_cores(), 0);
        assert_eq!(n.free_mem_mib(), 0);
        // Second whole-node allocation fails.
        assert!(n.allocate_whole().is_err());
    }

    #[test]
    fn release_unallocated_is_error() {
        let mut n = node();
        let mut ghost = CoreMask::empty(64);
        ghost.set(10);
        assert!(n.release(&ghost, 0).is_err());
    }

    #[test]
    fn double_release_is_error() {
        let mut n = node();
        let m = n.allocate(2, 64).unwrap();
        n.release(&m, 64).unwrap();
        assert!(n.release(&m, 0).is_err());
    }
}
