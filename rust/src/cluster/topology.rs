//! Cluster topology: the node table, allocation queries and reservations.

use crate::cluster::affinity::CoreMask;
use crate::cluster::node::{Node, NodeId, NodeState};
use crate::error::{Error, Result};

/// A named node reservation. The paper ran most benchmarks on a reserved
/// slice of the production system; reservations fence nodes off so only
/// jobs tagged with the reservation may allocate them.
#[derive(Debug, Clone)]
pub struct Reservation {
    pub name: String,
    pub nodes: Vec<NodeId>,
}

/// The cluster: homogeneous node table plus reservations.
#[derive(Debug)]
pub struct Cluster {
    nodes: Vec<Node>,
    reservations: Vec<Reservation>,
}

impl Cluster {
    /// Homogeneous cluster of `n_nodes` × `cores` cores, `mem_mib` each.
    pub fn homogeneous(n_nodes: u32, cores: u32, mem_mib: u64) -> Cluster {
        Cluster {
            nodes: (0..n_nodes).map(|i| Node::new(i, cores, mem_mib)).collect(),
            reservations: Vec::new(),
        }
    }

    /// TX-Green-like slice: `n_nodes` × 64 cores × 192 GiB (paper §III.A).
    pub fn tx_green(n_nodes: u32) -> Cluster {
        Cluster::homogeneous(n_nodes, 64, 192 * 1024)
    }

    /// Heterogeneous cluster from `(count, cores, mem_mib)` groups, ids
    /// assigned densely in group order. The placement index keys its
    /// idle pool by per-node capacity, so mixed node sizes are fully
    /// supported on the indexed dispatch path.
    pub fn heterogeneous(groups: &[(u32, u32, u64)]) -> Cluster {
        let mut nodes = Vec::new();
        for &(count, cores, mem_mib) in groups {
            for _ in 0..count {
                let id = nodes.len() as NodeId;
                nodes.push(Node::new(id, cores, mem_mib));
            }
        }
        Cluster {
            nodes,
            reservations: Vec::new(),
        }
    }

    pub fn n_nodes(&self) -> u32 {
        self.nodes.len() as u32
    }

    /// Total cores across all nodes.
    pub fn total_cores(&self) -> u64 {
        self.nodes.iter().map(|n| n.cores as u64).sum()
    }

    /// Cores currently allocated across all nodes.
    pub fn busy_cores(&self) -> u64 {
        self.nodes.iter().map(|n| n.busy_cores() as u64).sum()
    }

    pub fn node(&self, id: NodeId) -> Result<&Node> {
        self.nodes.get(id as usize).ok_or(Error::UnknownId {
            kind: "node",
            id: id as u64,
        })
    }

    pub fn node_mut(&mut self, id: NodeId) -> Result<&mut Node> {
        self.nodes.get_mut(id as usize).ok_or(Error::UnknownId {
            kind: "node",
            id: id as u64,
        })
    }

    /// Iterate all nodes.
    pub fn nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter()
    }

    /// Create a reservation over explicit node ids.
    pub fn reserve(&mut self, name: &str, nodes: Vec<NodeId>) -> Result<()> {
        for &id in &nodes {
            self.node(id)?; // validate
            if self.reservations.iter().any(|r| r.nodes.contains(&id)) {
                return Err(Error::Infeasible(format!(
                    "node {id} already in another reservation"
                )));
            }
        }
        self.reservations.push(Reservation {
            name: name.to_string(),
            nodes,
        });
        Ok(())
    }

    /// Look up a reservation by name.
    pub fn reservation(&self, name: &str) -> Option<&Reservation> {
        self.reservations.iter().find(|r| r.name == name)
    }

    /// All reservations, in creation order (the placement index
    /// partitions its buckets by this list).
    pub fn reservations(&self) -> &[Reservation] {
        &self.reservations
    }

    /// Nodes eligible for a job: inside its reservation if named, else all
    /// unreserved nodes.
    pub fn eligible_nodes(&self, reservation: Option<&str>) -> Vec<NodeId> {
        match reservation {
            Some(name) => self
                .reservation(name)
                .map(|r| r.nodes.clone())
                .unwrap_or_default(),
            None => {
                let reserved: Vec<NodeId> = self
                    .reservations
                    .iter()
                    .flat_map(|r| r.nodes.iter().copied())
                    .collect();
                self.nodes
                    .iter()
                    .map(|n| n.id)
                    .filter(|id| !reserved.contains(id))
                    .collect()
            }
        }
    }

    /// Find up to `want` *wholly idle* eligible nodes (node-based path).
    pub fn find_idle_nodes(&self, want: u32, reservation: Option<&str>) -> Vec<NodeId> {
        self.eligible_nodes(reservation)
            .into_iter()
            .filter(|&id| {
                let n = &self.nodes[id as usize];
                n.state() == NodeState::Up && n.is_idle()
            })
            .take(want as usize)
            .collect()
    }

    /// Find one node that can host `cores` cores + `mem_mib` (first-fit
    /// scan, no allocation) — the scan baseline the indexed placement
    /// subsystem ([`crate::placement`]) is benchmarked against; the
    /// dispatch hot path now goes through the index.
    ///
    /// Down/draining nodes are excluded with an explicit
    /// [`NodeState::Up`] guard, matching [`Cluster::find_idle_nodes`].
    /// (`can_fit` also enforces it, but placement searches must never
    /// rely on a node-local check alone: a regression here would place
    /// core-level tasks on drained nodes.)
    pub fn find_fit_node(
        &self,
        cores: u32,
        mem_mib: u64,
        reservation: Option<&str>,
    ) -> Option<NodeId> {
        let in_reservation = |id: NodeId| -> bool {
            match reservation {
                Some(name) => self
                    .reservation(name)
                    .map(|r| r.nodes.contains(&id))
                    .unwrap_or(false),
                None => !self.reservations.iter().any(|r| r.nodes.contains(&id)),
            }
        };
        self.nodes
            .iter()
            .find(|n| {
                n.state() == NodeState::Up && n.can_fit(cores, mem_mib) && in_reservation(n.id)
            })
            .map(|n| n.id)
    }

    /// Find `(node, cores)` placements totalling `want_cores` cores using
    /// best-fit-decreasing over free cores (multi-level / per-core path).
    pub fn find_core_slots(
        &self,
        want_cores: u64,
        max_per_node: u32,
        reservation: Option<&str>,
    ) -> Vec<(NodeId, u32)> {
        let mut frees: Vec<(NodeId, u32)> = self
            .eligible_nodes(reservation)
            .into_iter()
            .filter_map(|id| {
                let n = &self.nodes[id as usize];
                if n.state() == NodeState::Up && n.free_cores() > 0 {
                    Some((id, n.free_cores().min(max_per_node)))
                } else {
                    None
                }
            })
            .collect();
        // Most-free-first keeps placements dense (fewer partial nodes).
        frees.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let mut out = Vec::new();
        let mut left = want_cores;
        for (id, free) in frees {
            if left == 0 {
                break;
            }
            let take = (free as u64).min(left) as u32;
            out.push((id, take));
            left -= take as u64;
        }
        out
    }

    /// Allocate `cores` on a node, returning the pinned mask.
    pub fn allocate_on(&mut self, id: NodeId, cores: u32, mem_mib: u64) -> Result<CoreMask> {
        self.node_mut(id)?.allocate(cores, mem_mib)
    }

    /// Release an allocation.
    pub fn release_on(&mut self, id: NodeId, mask: &CoreMask, mem_mib: u64) -> Result<()> {
        self.node_mut(id)?.release(mask, mem_mib)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tx_green_shape() {
        let c = Cluster::tx_green(32);
        assert_eq!(c.n_nodes(), 32);
        assert_eq!(c.total_cores(), 32 * 64);
        assert_eq!(c.busy_cores(), 0);
    }

    #[test]
    fn heterogeneous_shape() {
        let c = Cluster::heterogeneous(&[(2, 64, 1024), (3, 16, 512)]);
        assert_eq!(c.n_nodes(), 5);
        assert_eq!(c.total_cores(), 2 * 64 + 3 * 16);
        assert_eq!(c.node(0).unwrap().cores, 64);
        assert_eq!(c.node(4).unwrap().cores, 16);
        // Ids are dense and in group order.
        let ids: Vec<u32> = c.nodes().map(|n| n.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn unknown_node_is_error() {
        let c = Cluster::tx_green(2);
        assert!(c.node(5).is_err());
    }

    #[test]
    fn idle_node_search_respects_occupancy() {
        let mut c = Cluster::tx_green(4);
        c.allocate_on(1, 1, 0).unwrap();
        let idle = c.find_idle_nodes(10, None);
        assert_eq!(idle, vec![0, 2, 3]);
    }

    #[test]
    fn core_slot_search_spans_nodes() {
        let mut c = Cluster::tx_green(3);
        c.allocate_on(0, 60, 0).unwrap(); // 4 free
        let slots = c.find_core_slots(70, 64, None);
        let total: u64 = slots.iter().map(|(_, k)| *k as u64).sum();
        assert_eq!(total, 70);
        // Best-fit: fully-free nodes (64) come before the 4-free node.
        assert_eq!(slots[0].1, 64);
    }

    #[test]
    fn core_slot_search_partial_when_scarce() {
        let c = Cluster::tx_green(1);
        let slots = c.find_core_slots(100, 64, None);
        let total: u64 = slots.iter().map(|(_, k)| *k as u64).sum();
        assert_eq!(total, 64, "only 64 cores exist");
    }

    #[test]
    fn reservations_fence_nodes() {
        let mut c = Cluster::tx_green(4);
        c.reserve("bench", vec![0, 1]).unwrap();
        assert_eq!(c.eligible_nodes(Some("bench")), vec![0, 1]);
        assert_eq!(c.eligible_nodes(None), vec![2, 3]);
        // Overlapping reservation rejected.
        assert!(c.reserve("other", vec![1]).is_err());
    }

    #[test]
    fn max_per_node_cap_respected() {
        let c = Cluster::tx_green(2);
        let slots = c.find_core_slots(64, 16, None);
        assert!(slots.iter().all(|(_, k)| *k <= 16));
        let total: u64 = slots.iter().map(|(_, k)| *k as u64).sum();
        assert_eq!(total, 32, "2 nodes × 16 cap");
    }

    #[test]
    fn allocate_release_updates_busy_count() {
        let mut c = Cluster::tx_green(2);
        let m = c.allocate_on(0, 10, 100).unwrap();
        assert_eq!(c.busy_cores(), 10);
        c.release_on(0, &m, 100).unwrap();
        assert_eq!(c.busy_cores(), 0);
    }

    #[test]
    fn down_nodes_excluded_from_search() {
        let mut c = Cluster::tx_green(2);
        c.node_mut(0).unwrap().set_state(NodeState::Down);
        assert_eq!(c.find_idle_nodes(2, None), vec![1]);
        let slots = c.find_core_slots(128, 64, None);
        let total: u64 = slots.iter().map(|(_, k)| *k as u64).sum();
        assert_eq!(total, 64);
    }

    #[test]
    fn fit_search_skips_down_and_draining_nodes() {
        // Regression: find_fit_node must apply the same NodeState::Up
        // guard as find_idle_nodes, or core-level tasks land on drained
        // nodes.
        let mut c = Cluster::tx_green(3);
        c.node_mut(0).unwrap().set_state(NodeState::Down);
        c.node_mut(1).unwrap().set_state(NodeState::Draining);
        assert_eq!(c.find_fit_node(1, 0, None), Some(2));
        c.node_mut(2).unwrap().set_state(NodeState::Down);
        assert_eq!(c.find_fit_node(1, 0, None), None);
        // Recovery is visible again.
        c.node_mut(1).unwrap().set_state(NodeState::Up);
        assert_eq!(c.find_fit_node(1, 0, None), Some(1));
    }

    #[test]
    fn reservations_accessor_lists_in_order() {
        let mut c = Cluster::tx_green(6);
        c.reserve("a", vec![0, 1]).unwrap();
        c.reserve("b", vec![2]).unwrap();
        let names: Vec<&str> = c.reservations().iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b"]);
    }
}
