//! Core masks and process-affinity control.
//!
//! Node-based scheduling's script generator emits explicit per-process core
//! pinning ("holistically pinning processes to cores" — paper §I). In the
//! DES the mask is bookkeeping; in the real executor ([`crate::exec`]) the
//! same mask is applied with `sched_setaffinity(2)`.

use std::fmt;

/// A set of cores on one node, packed as 64-bit words.
#[derive(Clone, PartialEq, Eq)]
pub struct CoreMask {
    words: Vec<u64>,
    ncores: u32,
}

impl CoreMask {
    /// Empty mask over a node with `ncores` cores.
    pub fn empty(ncores: u32) -> CoreMask {
        CoreMask {
            words: vec![0; ((ncores as usize) + 63) / 64],
            ncores,
        }
    }

    /// Mask with all `ncores` cores set.
    pub fn full(ncores: u32) -> CoreMask {
        let mut m = CoreMask::empty(ncores);
        for c in 0..ncores {
            m.set(c);
        }
        m
    }

    /// Mask with a contiguous range `[lo, hi)` set.
    pub fn range(ncores: u32, lo: u32, hi: u32) -> CoreMask {
        assert!(lo <= hi && hi <= ncores, "bad core range {lo}..{hi}");
        let mut m = CoreMask::empty(ncores);
        for c in lo..hi {
            m.set(c);
        }
        m
    }

    /// Node core count this mask ranges over.
    pub fn ncores(&self) -> u32 {
        self.ncores
    }

    /// Set one core bit.
    pub fn set(&mut self, core: u32) {
        assert!(core < self.ncores, "core {core} out of range");
        self.words[(core / 64) as usize] |= 1u64 << (core % 64);
    }

    /// Test one core bit.
    pub fn get(&self, core: u32) -> bool {
        if core >= self.ncores {
            return false;
        }
        self.words[(core / 64) as usize] & (1u64 << (core % 64)) != 0
    }

    /// Number of set cores.
    pub fn count(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// True if `other` ⊆ `self`.
    pub fn contains(&self, other: &CoreMask) -> bool {
        self.words
            .iter()
            .zip(&other.words)
            .all(|(a, b)| a & b == *b)
    }

    /// Remove all cores in `other` from `self`.
    pub fn clear(&mut self, other: &CoreMask) {
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= !b;
        }
    }

    /// Claim the `n` lowest-indexed *unset* cores, setting them in `self`
    /// and returning them as a new mask. Caller must ensure capacity.
    pub fn take_lowest_free(&mut self, n: u32) -> CoreMask {
        let mut taken = CoreMask::empty(self.ncores);
        let mut left = n;
        for c in 0..self.ncores {
            if left == 0 {
                break;
            }
            if !self.get(c) {
                self.set(c);
                taken.set(c);
                left -= 1;
            }
        }
        assert_eq!(left, 0, "take_lowest_free: not enough free cores");
        taken
    }

    /// Iterate set core indices in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        (0..self.ncores).filter(move |&c| self.get(c))
    }

    /// Render as a `taskset`-style hex string (lowest core = LSB).
    pub fn to_hex(&self) -> String {
        let mut s = String::from("0x");
        let mut started = false;
        for w in self.words.iter().rev() {
            if started {
                s.push_str(&format!("{w:016x}"));
            } else if *w != 0 || self.words.len() == 1 {
                s.push_str(&format!("{w:x}"));
                started = true;
            }
        }
        if !started {
            s.push('0');
        }
        s
    }

    /// Render as a cpu-list string (`0-3,8,12-15`), the format used in the
    /// generated node scripts and accepted by `taskset -c`.
    pub fn to_cpulist(&self) -> String {
        let cores: Vec<u32> = self.iter().collect();
        let mut parts: Vec<String> = Vec::new();
        let mut i = 0;
        while i < cores.len() {
            let start = cores[i];
            let mut end = start;
            while i + 1 < cores.len() && cores[i + 1] == end + 1 {
                i += 1;
                end = cores[i];
            }
            if start == end {
                parts.push(format!("{start}"));
            } else {
                parts.push(format!("{start}-{end}"));
            }
            i += 1;
        }
        parts.join(",")
    }

    /// Apply this mask to the calling thread with `sched_setaffinity(2)`.
    /// No-op error on platforms without it. Used by the real executor.
    ///
    /// Hand-rolled FFI: the offline build vendors no `libc` crate.
    /// `cpu_set_t` on Linux is a fixed 1024-bit mask.
    #[cfg(target_os = "linux")]
    pub fn apply_to_current_thread(&self) -> std::io::Result<()> {
        const CPU_SETSIZE: u32 = 1024;
        const WORDS: usize = (CPU_SETSIZE as usize) / 64;
        /// `_SC_NPROCESSORS_ONLN` on Linux.
        const SC_NPROCESSORS_ONLN: i32 = 84;
        extern "C" {
            // pid 0 = the calling thread.
            fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
            fn sysconf(name: i32) -> i64;
        }
        // Online-CPU count, NOT available_parallelism(): the latter
        // reflects the process's current affinity mask, which would make
        // pinning silently skip cores outside an inherited mask.
        let ncpu = match unsafe { sysconf(SC_NPROCESSORS_ONLN) } {
            n if n > 0 => n as u32,
            _ => 1,
        };
        let mut set = [0u64; WORDS];
        let mut any = false;
        for c in self.iter() {
            if c < ncpu && c < CPU_SETSIZE {
                set[(c / 64) as usize] |= 1u64 << (c % 64);
                any = true;
            }
        }
        if !any {
            // Mask refers only to cores this host doesn't have (e.g. a
            // 64-core script on a small dev box): leave affinity alone.
            return Ok(());
        }
        let rc = unsafe { sched_setaffinity(0, WORDS * 8, set.as_ptr()) };
        if rc != 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(())
    }
}

impl fmt::Debug for CoreMask {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "CoreMask({})", self.to_cpulist())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_full() {
        assert_eq!(CoreMask::empty(64).count(), 0);
        assert_eq!(CoreMask::full(64).count(), 64);
        assert_eq!(CoreMask::full(65).count(), 65);
    }

    #[test]
    fn set_get_clear() {
        let mut m = CoreMask::empty(128);
        m.set(0);
        m.set(63);
        m.set(64);
        m.set(127);
        assert!(m.get(0) && m.get(63) && m.get(64) && m.get(127));
        assert!(!m.get(1));
        assert_eq!(m.count(), 4);
        let mut rm = CoreMask::empty(128);
        rm.set(63);
        m.clear(&rm);
        assert!(!m.get(63));
        assert_eq!(m.count(), 3);
    }

    #[test]
    fn contains_subset() {
        let big = CoreMask::range(64, 0, 8);
        let small = CoreMask::range(64, 2, 5);
        assert!(big.contains(&small));
        assert!(!small.contains(&big));
        assert!(big.contains(&CoreMask::empty(64)));
    }

    #[test]
    fn take_lowest_free_skips_taken() {
        let mut m = CoreMask::empty(16);
        m.set(0);
        m.set(2);
        let t = m.take_lowest_free(3);
        assert_eq!(t.iter().collect::<Vec<_>>(), vec![1, 3, 4]);
        assert_eq!(m.count(), 5);
    }

    #[test]
    #[should_panic(expected = "not enough free cores")]
    fn take_lowest_free_overflow_panics() {
        let mut m = CoreMask::full(4);
        m.take_lowest_free(1);
    }

    #[test]
    fn cpulist_formats() {
        let mut m = CoreMask::empty(32);
        for c in [0, 1, 2, 3, 8, 12, 13, 14, 15] {
            m.set(c);
        }
        assert_eq!(m.to_cpulist(), "0-3,8,12-15");
        assert_eq!(CoreMask::empty(8).to_cpulist(), "");
        let mut single = CoreMask::empty(8);
        single.set(5);
        assert_eq!(single.to_cpulist(), "5");
    }

    #[test]
    fn hex_formats() {
        let m = CoreMask::range(64, 0, 4);
        assert_eq!(m.to_hex(), "0xf");
        let mut hi = CoreMask::empty(128);
        hi.set(64);
        assert_eq!(hi.to_hex(), "0x10000000000000000");
        assert_eq!(CoreMask::empty(8).to_hex(), "0x0");
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn apply_affinity_smoke() {
        // Pin to core 0 (always exists); must not error.
        let mut m = CoreMask::empty(1);
        m.set(0);
        m.apply_to_current_thread().unwrap();
        // Out-of-range-only mask is a no-op, not an error.
        let mut far = CoreMask::empty(4096);
        far.set(4095);
        far.apply_to_current_thread().unwrap();
    }
}
