//! Cluster model: nodes, cores, memory, affinity and topology.
//!
//! Substrate for the paper's testbed (TX-Green: 64-core Xeon Phi nodes).
//! The model tracks per-node core occupancy and memory, node lifecycle
//! states, and named reservations (the paper ran most benchmarks on a
//! reserved slice of the production machine).

pub mod affinity;
pub mod node;
pub mod topology;

pub use affinity::CoreMask;
pub use node::{Node, NodeId, NodeState};
pub use topology::{Cluster, Reservation};
