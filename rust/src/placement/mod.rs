//! The placement subsystem: pluggable placement policies over an
//! incrementally-maintained free-capacity index.
//!
//! The paper's headline claim is that node-based scheduling launches
//! large arrays of short jobs ~100× faster than task-level scheduling.
//! For the *simulator's own* dispatch hot path to exhibit the same
//! asymptotics, placement queries must not scan the node table: a
//! 16384-node cluster answering "give me an idle node" with an O(N)
//! walk pays the task-level cost structure all over again.
//!
//! This module provides:
//!
//! * [`FreeIndex`] — an index over the cluster maintained by
//!   allocate/release deltas: an idle-node pool plus free-core-count
//!   buckets, partitioned by reservation, answering whole-node and
//!   `cores + mem` fit queries in O(buckets · log n) instead of
//!   O(nodes) ([`free_index`]);
//! * [`PlacementPolicy`] — the strategy interface with five
//!   implementations: first-fit, best-fit, spread (worst-fit), random,
//!   and the paper's node-based fast path ([`policy`]);
//! * [`PlacementEngine`] — the façade the scheduler talks to: it owns
//!   the index and the policy, wraps cluster allocate/release so the
//!   index never desynchronizes, and hands back
//!   [`crate::scheduler::job::Placement`]s;
//! * [`ReservationLedger`] — earliest-start backfill reservations for
//!   blocked whole-node jobs, planned from the index plus expected
//!   completion times, with the admission rules the dispatch loop
//!   enforces while a hold is active ([`backfill`]).
//!
//! Policy selection threads through every layer: config files
//! (`placement = "best-fit"`), the `--placement` CLI flag, experiment
//! presets, and the aggregation modes (each mode names its default via
//! [`crate::aggregation::plan::Aggregator::default_strategy`]).

pub mod backfill;
pub mod free_index;
pub mod policy;

pub use backfill::{Hold, ReservationLedger};
pub use free_index::FreeIndex;
pub use policy::{policy_for, PlacementEngine, PlacementPolicy};

use crate::error::{Error, Result};

/// Which placement strategy a run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Lowest-numbered node that fits (matches the historical linear
    /// scan, so it is the default for core-level aggregation modes).
    FirstFit,
    /// Node with the fewest sufficient free cores (densest packing).
    BestFit,
    /// Node with the most free cores (worst-fit; spreads load, keeps
    /// whole nodes free for incoming node-level jobs).
    Spread,
    /// Uniformly random fitting node (seeded; baseline for comparisons).
    Random,
    /// The paper's node-based fast path: O(log n) pop from the idle
    /// pool for whole-node requests, best-fit for stray core requests.
    NodeBased,
}

/// All strategies, for sweeps and exhaustive tests.
pub const ALL_STRATEGIES: [Strategy; 5] = [
    Strategy::FirstFit,
    Strategy::BestFit,
    Strategy::Spread,
    Strategy::Random,
    Strategy::NodeBased,
];

impl Strategy {
    /// Parse from the names used in configs and CLI flags.
    pub fn parse(s: &str) -> Result<Strategy> {
        match s {
            "first-fit" | "first_fit" | "ff" => Ok(Strategy::FirstFit),
            "best-fit" | "best_fit" | "bf" => Ok(Strategy::BestFit),
            "spread" | "worst-fit" | "worst_fit" | "wf" => Ok(Strategy::Spread),
            "random" | "rand" => Ok(Strategy::Random),
            "node-based" | "node_based" | "fast" | "nb" => Ok(Strategy::NodeBased),
            other => Err(Error::Config(format!("unknown placement strategy {other:?}"))),
        }
    }
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Strategy::FirstFit => "first-fit",
            Strategy::BestFit => "best-fit",
            Strategy::Spread => "spread",
            Strategy::Random => "random",
            Strategy::NodeBased => "node-based",
        };
        write!(f, "{s}")
    }
}

impl Default for Strategy {
    fn default() -> Self {
        Strategy::FirstFit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_aliases() {
        assert_eq!(Strategy::parse("first-fit").unwrap(), Strategy::FirstFit);
        assert_eq!(Strategy::parse("bf").unwrap(), Strategy::BestFit);
        assert_eq!(Strategy::parse("worst-fit").unwrap(), Strategy::Spread);
        assert_eq!(Strategy::parse("random").unwrap(), Strategy::Random);
        assert_eq!(Strategy::parse("node_based").unwrap(), Strategy::NodeBased);
        assert!(Strategy::parse("bogus").is_err());
    }

    #[test]
    fn display_roundtrips_through_parse() {
        for s in ALL_STRATEGIES {
            assert_eq!(Strategy::parse(&s.to_string()).unwrap(), s);
        }
    }
}
