//! The free-capacity index: incremental bookkeeping over the cluster.
//!
//! [`FreeIndex`] answers the two placement questions the dispatch hot
//! path asks — "give me an idle node" (node-based path) and "give me a
//! node with `cores` free cores and `mem` free MiB" (core-level path) —
//! without scanning the node table. It keeps, per reservation
//! partition, one `BTreeSet<NodeId>` bucket per free-core count; a node
//! always sits in exactly one bucket (its current free-core count), and
//! moves between buckets on every allocate/release delta. Alongside the
//! buckets each partition keeps an explicit *idle pool*: the set of
//! nodes whose free count equals their own capacity. On a homogeneous
//! cluster that is the full bucket, but tracking it per node makes the
//! whole-node queries correct on mixed node sizes too (a wholly idle
//! 32-core node is idle even when the largest node has 64 cores).
//! Whole-node queries are an O(log n) set lookup and fit queries walk
//! at most `cores_per_node` buckets instead of every node.
//!
//! Down/draining nodes are *not indexed* (mirroring the `NodeState::Up`
//! guard of the scan-based search paths), and every candidate the index
//! proposes is re-checked with [`crate::cluster::Node::can_fit`] before
//! use, so a desynchronized index can cause a slow answer but never a
//! wrong one. `check_consistency` asserts full agreement with a
//! brute-force cluster scan; the property tests in
//! `rust/tests/placement_properties.rs` drive it under randomized
//! allocate/release sequences.

use crate::cluster::{Cluster, NodeId, NodeState};
use crate::util::rng::Rng;
use std::collections::BTreeSet;

/// Per-partition free-core buckets plus the idle pool.
#[derive(Debug, Clone, Default)]
struct PartitionBuckets {
    /// `buckets[c]` = ids of indexed nodes with exactly `c` free cores.
    buckets: Vec<BTreeSet<NodeId>>,
    /// Indexed nodes whose free count equals their *own* capacity —
    /// wholly idle regardless of per-node core count, so the pool stays
    /// correct on heterogeneous clusters.
    idle: BTreeSet<NodeId>,
}

/// The incrementally-maintained free-capacity index.
#[derive(Debug, Clone)]
pub struct FreeIndex {
    /// Cores on the largest node (bucket count − 1).
    cores_per_node: u32,
    /// Reservation names; reservation `r` is partition `r + 1`,
    /// unreserved nodes are partition 0.
    names: Vec<String>,
    /// Node → partition id.
    partition: Vec<u32>,
    /// Node → physical core count (idle-pool membership test).
    capacity: Vec<u32>,
    /// Node → cached free-core count (valid for indexed nodes).
    free: Vec<u32>,
    /// Node → currently present in the buckets (i.e. was `Up` at the
    /// last build/state refresh).
    indexed: Vec<bool>,
    parts: Vec<PartitionBuckets>,
}

impl FreeIndex {
    /// Build the index from the cluster's current state (node states,
    /// existing allocations, reservations).
    pub fn build(cluster: &Cluster) -> FreeIndex {
        let cores_per_node = cluster.nodes().map(|n| n.cores).max().unwrap_or(0);
        let names: Vec<String> = cluster
            .reservations()
            .iter()
            .map(|r| r.name.clone())
            .collect();
        let n_nodes = cluster.n_nodes() as usize;
        let mut partition = vec![0u32; n_nodes];
        for (r, res) in cluster.reservations().iter().enumerate() {
            for &id in &res.nodes {
                partition[id as usize] = r as u32 + 1;
            }
        }
        let empty = PartitionBuckets {
            buckets: vec![BTreeSet::new(); cores_per_node as usize + 1],
            idle: BTreeSet::new(),
        };
        let mut idx = FreeIndex {
            cores_per_node,
            names,
            partition,
            capacity: vec![0; n_nodes],
            free: vec![0; n_nodes],
            indexed: vec![false; n_nodes],
            parts: vec![empty; cluster.reservations().len() + 1],
        };
        for node in cluster.nodes() {
            let id = node.id as usize;
            let free = node.free_cores();
            idx.capacity[id] = node.cores;
            idx.free[id] = free;
            if node.state() == NodeState::Up {
                idx.indexed[id] = true;
                let part = idx.partition[id] as usize;
                idx.parts[part].buckets[free as usize].insert(node.id);
                if free == node.cores {
                    idx.parts[part].idle.insert(node.id);
                }
            }
        }
        idx
    }

    /// Cores on the (largest) node; buckets run `0..=cores_per_node`.
    pub fn cores_per_node(&self) -> u32 {
        self.cores_per_node
    }

    /// Physical core count of one node (cached at build time).
    pub fn node_capacity(&self, id: NodeId) -> u32 {
        self.capacity[id as usize]
    }

    /// Indexed (`Up`) nodes of a partition, ascending by id. O(nodes) —
    /// for occasional planning passes (backfill reservations), not the
    /// dispatch hot path.
    pub fn partition_nodes(&self, part: u32) -> Vec<NodeId> {
        self.partition_nodes_iter(part).collect()
    }

    /// Allocation-free variant of [`Self::partition_nodes`]: the hold
    /// planner walks a partition once per reservation candidate, so it
    /// must not materialize a `Vec` each pass.
    pub fn partition_nodes_iter(&self, part: u32) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.partition.len())
            .filter(move |&i| self.indexed[i] && self.partition[i] == part)
            .map(|i| i as NodeId)
    }

    /// Resolve a reservation name to a partition id. `None` reservation
    /// is the unreserved partition 0; an unknown name yields `None`
    /// (no eligible nodes), matching the scan-based search semantics.
    pub fn partition_for(&self, reservation: Option<&str>) -> Option<u32> {
        match reservation {
            None => Some(0),
            Some(name) => self
                .names
                .iter()
                .position(|n| n == name)
                .map(|i| i as u32 + 1),
        }
    }

    /// Apply an allocate/release delta: node `id` now has `new_free`
    /// free cores. O(log n).
    pub fn on_delta(&mut self, id: NodeId, new_free: u32) {
        let i = id as usize;
        debug_assert!(new_free <= self.cores_per_node);
        let old_free = self.free[i];
        if self.indexed[i] && old_free != new_free {
            let part = self.partition[i] as usize;
            self.parts[part].buckets[old_free as usize].remove(&id);
            self.parts[part].buckets[new_free as usize].insert(id);
            if new_free == self.capacity[i] {
                self.parts[part].idle.insert(id);
            } else {
                self.parts[part].idle.remove(&id);
            }
        }
        self.free[i] = new_free;
    }

    /// Apply a node lifecycle change: only `Up` nodes are indexed.
    pub fn on_state_change(&mut self, id: NodeId, state: NodeState) {
        let i = id as usize;
        let up = state == NodeState::Up;
        let part = self.partition[i] as usize;
        let free = self.free[i] as usize;
        if up && !self.indexed[i] {
            self.parts[part].buckets[free].insert(id);
            if self.free[i] == self.capacity[i] {
                self.parts[part].idle.insert(id);
            }
            self.indexed[i] = true;
        } else if !up && self.indexed[i] {
            self.parts[part].buckets[free].remove(&id);
            self.parts[part].idle.remove(&id);
            self.indexed[i] = false;
        }
    }

    // ---- whole-node (idle pool) queries --------------------------------
    //
    // The idle pool tracks nodes whose free count equals their own
    // capacity, so these queries are correct on heterogeneous clusters
    // (nodes of mixed core counts) as well as homogeneous ones. Every
    // candidate is still re-checked with `is_idle` (memory edge cases).

    fn idle_pool(&self, part: u32) -> &BTreeSet<NodeId> {
        &self.parts[part as usize].idle
    }

    /// Lowest-numbered wholly idle node in the partition.
    pub fn idle_lowest(&self, cluster: &Cluster, part: u32) -> Option<NodeId> {
        self.idle_pool(part)
            .iter()
            .copied()
            .find(|&n| is_idle(cluster, n))
    }

    /// Lowest-numbered wholly idle node passing `allow` (backfill holds
    /// exclude nodes reserved for a pending whole-node job).
    pub fn idle_lowest_where<F: Fn(NodeId) -> bool>(
        &self,
        cluster: &Cluster,
        part: u32,
        allow: F,
    ) -> Option<NodeId> {
        self.idle_pool(part)
            .iter()
            .copied()
            .find(|&n| allow(n) && is_idle(cluster, n))
    }

    /// Highest-numbered wholly idle node — the node-based fast path's
    /// O(log n) "pop" (any idle node is as good as any other for a
    /// whole-node request; taking from one end avoids ordering work).
    pub fn idle_highest(&self, cluster: &Cluster, part: u32) -> Option<NodeId> {
        self.idle_pool(part)
            .iter()
            .rev()
            .copied()
            .find(|&n| is_idle(cluster, n))
    }

    /// Uniformly random idle node.
    pub fn idle_random(&self, cluster: &Cluster, part: u32, rng: &mut Rng) -> Option<NodeId> {
        let pool = self.idle_pool(part);
        if pool.is_empty() {
            return None;
        }
        let k = rng.below(pool.len() as u64) as usize;
        // Probe from a random start; wrap to the front if the tail of
        // the pool has no idle node (mem edge cases only).
        pool.iter()
            .skip(k)
            .chain(pool.iter().take(k))
            .copied()
            .find(|&n| is_idle(cluster, n))
    }

    /// Number of wholly idle nodes in the partition.
    pub fn idle_count(&self, cluster: &Cluster, part: u32) -> usize {
        self.idle_pool(part)
            .iter()
            .filter(|&&n| is_idle(cluster, n))
            .count()
    }

    // ---- cores + mem fit queries ---------------------------------------

    /// Lowest-numbered node that fits `cores` + `mem_mib` (the indexed
    /// equivalent of the historical first-fit scan). O(buckets · log n).
    pub fn first_fit(
        &self,
        cluster: &Cluster,
        part: u32,
        cores: u32,
        mem_mib: u64,
    ) -> Option<NodeId> {
        let mut best: Option<NodeId> = None;
        for c in cores..=self.cores_per_node {
            if let Some(n) = self.bucket_candidate(cluster, part, c, cores, mem_mib) {
                best = Some(match best {
                    Some(b) => b.min(n),
                    None => n,
                });
            }
        }
        best
    }

    /// Node with the fewest sufficient free cores (densest packing).
    pub fn best_fit(
        &self,
        cluster: &Cluster,
        part: u32,
        cores: u32,
        mem_mib: u64,
    ) -> Option<NodeId> {
        (cores..=self.cores_per_node)
            .find_map(|c| self.bucket_candidate(cluster, part, c, cores, mem_mib))
    }

    /// Node with the most free cores (worst-fit / spread).
    pub fn worst_fit(
        &self,
        cluster: &Cluster,
        part: u32,
        cores: u32,
        mem_mib: u64,
    ) -> Option<NodeId> {
        (cores..=self.cores_per_node)
            .rev()
            .find_map(|c| self.bucket_candidate(cluster, part, c, cores, mem_mib))
    }

    // ---- reservation-aware (filtered) fit queries ----------------------
    //
    // Backfill passes place around earliest-start holds: a candidate is
    // admissible only when the `allow` predicate accepts it (e.g. "not
    // the held node, unless the task vacates before the hold starts").

    /// Lowest-numbered node that fits and passes `allow`.
    pub fn first_fit_where<F: Fn(NodeId) -> bool>(
        &self,
        cluster: &Cluster,
        part: u32,
        cores: u32,
        mem_mib: u64,
        allow: F,
    ) -> Option<NodeId> {
        let mut best: Option<NodeId> = None;
        for c in cores..=self.cores_per_node {
            let cand = self.bucket_candidate_where(cluster, part, c, cores, mem_mib, &allow);
            if let Some(n) = cand {
                best = Some(match best {
                    Some(b) => b.min(n),
                    None => n,
                });
            }
        }
        best
    }

    /// Node with the fewest sufficient free cores that passes `allow`
    /// (densest packing among admissible nodes).
    pub fn best_fit_where<F: Fn(NodeId) -> bool>(
        &self,
        cluster: &Cluster,
        part: u32,
        cores: u32,
        mem_mib: u64,
        allow: F,
    ) -> Option<NodeId> {
        (cores..=self.cores_per_node)
            .find_map(|c| self.bucket_candidate_where(cluster, part, c, cores, mem_mib, &allow))
    }

    /// Uniformly random fitting node: pick a bucket weighted by size,
    /// then a random member. Falls back to [`Self::best_fit`] when the
    /// sampled candidate fails the memory check.
    ///
    /// Selection within a bucket is an O(bucket) walk (`BTreeSet` has
    /// no order-statistics); the random policy is a comparison
    /// baseline, not a hot path, so it trades speed for uniformity.
    pub fn random_fit(
        &self,
        cluster: &Cluster,
        part: u32,
        cores: u32,
        mem_mib: u64,
        rng: &mut Rng,
    ) -> Option<NodeId> {
        if cores > self.cores_per_node {
            return None;
        }
        let pb = &self.parts[part as usize];
        let total: usize = (cores..=self.cores_per_node)
            .map(|c| pb.buckets[c as usize].len())
            .sum();
        if total == 0 {
            return None;
        }
        let mut k = rng.below(total as u64) as usize;
        for c in cores..=self.cores_per_node {
            let bucket = &pb.buckets[c as usize];
            if k < bucket.len() {
                if let Some(&n) = bucket.iter().nth(k) {
                    if fits(cluster, n, cores, mem_mib) {
                        return Some(n);
                    }
                }
                // Sampled a node whose memory is too tight: fall back to
                // a deterministic search rather than resampling forever.
                return self.best_fit(cluster, part, cores, mem_mib);
            }
            k -= bucket.len();
        }
        None
    }

    /// Lowest-id member of one bucket passing the full fit check.
    fn bucket_candidate(
        &self,
        cluster: &Cluster,
        part: u32,
        bucket_free: u32,
        cores: u32,
        mem_mib: u64,
    ) -> Option<NodeId> {
        self.parts[part as usize].buckets[bucket_free as usize]
            .iter()
            .copied()
            .find(|&n| fits(cluster, n, cores, mem_mib))
    }

    /// Lowest-id member of one bucket passing the fit check and `allow`.
    fn bucket_candidate_where<F: Fn(NodeId) -> bool>(
        &self,
        cluster: &Cluster,
        part: u32,
        bucket_free: u32,
        cores: u32,
        mem_mib: u64,
        allow: &F,
    ) -> Option<NodeId> {
        self.parts[part as usize].buckets[bucket_free as usize]
            .iter()
            .copied()
            .find(|&n| allow(n) && fits(cluster, n, cores, mem_mib))
    }

    // ---- introspection / verification ----------------------------------

    /// Cached free-core count for a node (test/diagnostic helper).
    pub fn cached_free(&self, id: NodeId) -> u32 {
        self.free[id as usize]
    }

    /// Verify the index agrees with a brute-force scan of the cluster:
    /// every `Up` node sits in exactly the bucket of its free-core
    /// count, non-`Up` nodes are absent, and bucket totals match.
    pub fn check_consistency(&self, cluster: &Cluster) -> std::result::Result<(), String> {
        let mut bucketed = 0usize;
        for pb in &self.parts {
            bucketed += pb.buckets.iter().map(|b| b.len()).sum::<usize>();
        }
        let mut up_nodes = 0usize;
        for node in cluster.nodes() {
            let i = node.id as usize;
            let part = self.partition[i] as usize;
            let present = self.parts[part].buckets[node.free_cores() as usize].contains(&node.id);
            if node.state() == NodeState::Up {
                up_nodes += 1;
                if self.free[i] != node.free_cores() {
                    return Err(format!(
                        "node {}: cached free {} vs actual {}",
                        node.id,
                        self.free[i],
                        node.free_cores()
                    ));
                }
                if !present {
                    return Err(format!(
                        "node {}: missing from bucket {} of partition {part}",
                        node.id,
                        node.free_cores()
                    ));
                }
                let in_pool = self.parts[part].idle.contains(&node.id);
                let all_free = node.free_cores() == node.cores;
                if in_pool != all_free {
                    return Err(format!(
                        "node {}: idle-pool membership {in_pool} vs all-cores-free {all_free}",
                        node.id
                    ));
                }
            } else if self.indexed[i] {
                return Err(format!("node {}: not Up but still indexed", node.id));
            } else if self.parts[part].idle.contains(&node.id) {
                return Err(format!("node {}: not Up but still in the idle pool", node.id));
            }
        }
        if bucketed != up_nodes {
            return Err(format!(
                "{bucketed} bucketed entries vs {up_nodes} Up nodes"
            ));
        }
        Ok(())
    }
}

fn fits(cluster: &Cluster, id: NodeId, cores: u32, mem_mib: u64) -> bool {
    cluster
        .node(id)
        .map(|n| n.can_fit(cores, mem_mib))
        .unwrap_or(false)
}

fn is_idle(cluster: &Cluster, id: NodeId) -> bool {
    cluster
        .node(id)
        .map(|n| n.state() == NodeState::Up && n.is_idle())
        .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn index_over(cluster: &Cluster) -> FreeIndex {
        let idx = FreeIndex::build(cluster);
        idx.check_consistency(cluster).unwrap();
        idx
    }

    #[test]
    fn fresh_cluster_is_all_idle() {
        let c = Cluster::tx_green(8);
        let idx = index_over(&c);
        assert_eq!(idx.idle_count(&c, 0), 8);
        assert_eq!(idx.idle_lowest(&c, 0), Some(0));
        assert_eq!(idx.idle_highest(&c, 0), Some(7));
    }

    #[test]
    fn deltas_move_nodes_between_buckets() {
        let mut c = Cluster::tx_green(4);
        let mut idx = index_over(&c);
        c.allocate_on(1, 10, 0).unwrap();
        idx.on_delta(1, c.node(1).unwrap().free_cores());
        idx.check_consistency(&c).unwrap();
        assert_eq!(idx.idle_count(&c, 0), 3);
        assert_eq!(idx.cached_free(1), 54);
        // Fit query for 60 cores skips node 1 (only 54 free).
        assert_eq!(idx.first_fit(&c, 0, 60, 0), Some(0));
        // Best fit for 50 cores prefers the tightest node.
        assert_eq!(idx.best_fit(&c, 0, 50, 0), Some(1));
        // Spread prefers an untouched node.
        assert_eq!(idx.worst_fit(&c, 0, 1, 0), Some(0));
    }

    #[test]
    fn down_nodes_leave_the_index() {
        let mut c = Cluster::tx_green(3);
        let mut idx = index_over(&c);
        c.node_mut(0).unwrap().set_state(NodeState::Down);
        idx.on_state_change(0, NodeState::Down);
        idx.check_consistency(&c).unwrap();
        assert_eq!(idx.idle_lowest(&c, 0), Some(1));
        assert_eq!(idx.first_fit(&c, 0, 1, 0), Some(1));
        c.node_mut(0).unwrap().set_state(NodeState::Up);
        idx.on_state_change(0, NodeState::Up);
        assert_eq!(idx.idle_lowest(&c, 0), Some(0));
    }

    #[test]
    fn draining_nodes_also_leave_the_index() {
        let mut c = Cluster::tx_green(2);
        let mut idx = index_over(&c);
        c.node_mut(0).unwrap().set_state(NodeState::Draining);
        idx.on_state_change(0, NodeState::Draining);
        assert_eq!(idx.first_fit(&c, 0, 1, 0), Some(1));
        assert_eq!(idx.idle_count(&c, 0), 1);
    }

    #[test]
    fn reservations_partition_queries() {
        let mut c = Cluster::tx_green(4);
        c.reserve("bench", vec![0, 1]).unwrap();
        let idx = index_over(&c);
        let bench = idx.partition_for(Some("bench")).unwrap();
        let open = idx.partition_for(None).unwrap();
        assert_eq!(idx.idle_count(&c, bench), 2);
        assert_eq!(idx.idle_count(&c, open), 2);
        assert_eq!(idx.idle_lowest(&c, bench), Some(0));
        assert_eq!(idx.idle_lowest(&c, open), Some(2));
        assert_eq!(idx.partition_for(Some("nope")), None);
    }

    #[test]
    fn memory_limits_respected() {
        let mut c = Cluster::homogeneous(2, 4, 100);
        let mut idx = index_over(&c);
        c.allocate_on(0, 1, 90).unwrap();
        idx.on_delta(0, 3);
        // Node 0 has 3 free cores but only 10 MiB free.
        assert_eq!(idx.first_fit(&c, 0, 1, 50), Some(1));
        assert_eq!(idx.best_fit(&c, 0, 1, 50), Some(1));
        assert_eq!(idx.first_fit(&c, 0, 1, 5), Some(0));
        assert_eq!(idx.first_fit(&c, 0, 4, 0), Some(1), "4 cores need a free node");
        assert_eq!(idx.first_fit(&c, 0, 5, 0), None, "no node has 5 cores");
    }

    #[test]
    fn oversized_requests_yield_none() {
        let c = Cluster::tx_green(2);
        let idx = index_over(&c);
        assert_eq!(idx.first_fit(&c, 0, 65, 0), None);
        assert_eq!(idx.worst_fit(&c, 0, 65, 0), None);
        let mut rng = Rng::new(1);
        assert_eq!(idx.random_fit(&c, 0, 65, 0, &mut rng), None);
    }

    #[test]
    fn random_fit_is_uniformish_and_valid() {
        let c = Cluster::tx_green(16);
        let idx = index_over(&c);
        let mut rng = Rng::new(7);
        let mut seen = [0u32; 16];
        for _ in 0..1600 {
            let n = idx.random_fit(&c, 0, 1, 0, &mut rng).unwrap();
            seen[n as usize] += 1;
        }
        assert!(seen.iter().all(|&k| k > 0), "all nodes sampled: {seen:?}");
    }

    #[test]
    fn heterogeneous_idle_pool_sees_small_nodes() {
        // Nodes 0–1: 64 cores; nodes 2–3: 16 cores. A wholly idle
        // 16-core node must be in the idle pool even though the full
        // bucket sits at free == 64.
        let mut c = Cluster::heterogeneous(&[(2, 64, 1024), (2, 16, 512)]);
        let mut idx = index_over(&c);
        assert_eq!(idx.idle_count(&c, 0), 4);
        assert_eq!(idx.cores_per_node(), 64);
        assert_eq!(idx.node_capacity(0), 64);
        assert_eq!(idx.node_capacity(3), 16);
        // Occupy the big nodes: only the small ones stay idle.
        for id in 0..2 {
            c.node_mut(id).unwrap().allocate_whole().unwrap();
            idx.on_delta(id, 0);
        }
        idx.check_consistency(&c).unwrap();
        assert_eq!(idx.idle_count(&c, 0), 2);
        assert_eq!(idx.idle_lowest(&c, 0), Some(2));
        assert_eq!(idx.idle_highest(&c, 0), Some(3));
        // One core on node 2: it leaves the pool; release returns it.
        c.allocate_on(2, 1, 0).unwrap();
        idx.on_delta(2, 15);
        idx.check_consistency(&c).unwrap();
        assert_eq!(idx.idle_lowest(&c, 0), Some(3));
        // A 17-core fit query must skip the 16-core nodes entirely.
        assert_eq!(idx.first_fit(&c, 0, 17, 0), None);
        assert_eq!(idx.first_fit(&c, 0, 16, 0), Some(3));
    }

    #[test]
    fn heterogeneous_state_changes_keep_pool_consistent() {
        let mut c = Cluster::heterogeneous(&[(1, 8, 64), (1, 4, 64)]);
        let mut idx = index_over(&c);
        assert_eq!(idx.idle_count(&c, 0), 2);
        c.node_mut(1).unwrap().set_state(NodeState::Down);
        idx.on_state_change(1, NodeState::Down);
        idx.check_consistency(&c).unwrap();
        assert_eq!(idx.idle_count(&c, 0), 1);
        c.node_mut(1).unwrap().set_state(NodeState::Up);
        idx.on_state_change(1, NodeState::Up);
        idx.check_consistency(&c).unwrap();
        assert_eq!(idx.idle_count(&c, 0), 2);
        assert_eq!(idx.idle_highest(&c, 0), Some(1));
    }

    #[test]
    fn filtered_queries_respect_allow() {
        let c = Cluster::tx_green(4);
        let idx = index_over(&c);
        assert_eq!(idx.idle_lowest_where(&c, 0, |n| n != 0), Some(1));
        assert_eq!(idx.first_fit_where(&c, 0, 1, 0, |n| n >= 2), Some(2));
        assert_eq!(idx.best_fit_where(&c, 0, 1, 0, |n| n == 3), Some(3));
        assert_eq!(idx.best_fit_where(&c, 0, 1, 0, |_| false), None);
        // Unfiltered and trivially-filtered queries agree.
        assert_eq!(
            idx.first_fit(&c, 0, 2, 0),
            idx.first_fit_where(&c, 0, 2, 0, |_| true)
        );
    }

    #[test]
    fn partition_nodes_lists_up_members() {
        let mut c = Cluster::tx_green(4);
        c.reserve("bench", vec![1, 2]).unwrap();
        let mut idx = index_over(&c);
        let bench = idx.partition_for(Some("bench")).unwrap();
        assert_eq!(idx.partition_nodes(0), vec![0, 3]);
        assert_eq!(idx.partition_nodes(bench), vec![1, 2]);
        c.node_mut(1).unwrap().set_state(NodeState::Down);
        idx.on_state_change(1, NodeState::Down);
        assert_eq!(idx.partition_nodes(bench), vec![2]);
    }

    #[test]
    fn full_cluster_answers_none() {
        let mut c = Cluster::tx_green(2);
        let mut idx = index_over(&c);
        for id in 0..2 {
            c.node_mut(id).unwrap().allocate_whole().unwrap();
            idx.on_delta(id, 0);
        }
        idx.check_consistency(&c).unwrap();
        assert_eq!(idx.idle_count(&c, 0), 0);
        assert_eq!(idx.idle_highest(&c, 0), None);
        assert_eq!(idx.first_fit(&c, 0, 1, 0), None);
    }
}
