//! Placement policies and the engine that applies them.
//!
//! A [`PlacementPolicy`] turns free-capacity queries into a node choice;
//! the [`PlacementEngine`] owns the [`FreeIndex`] plus the active policy
//! and is the single choke point through which the scheduler allocates
//! and releases resources, so the index is maintained incrementally and
//! can never drift from the cluster.

use crate::cluster::{Cluster, NodeId, NodeState};
use crate::error::Result;
use crate::placement::free_index::FreeIndex;
use crate::placement::Strategy;
use crate::scheduler::job::Placement;
use crate::util::rng::Rng;

/// A placement strategy: picks a node for a request, given the index.
///
/// Policies are stateful only where the strategy demands it (the random
/// policy carries its seeded generator); everything else is a pure
/// query over the index.
pub trait PlacementPolicy {
    /// Which strategy this implements.
    fn strategy(&self) -> Strategy;

    /// Pick a node for a `cores` + `mem_mib` request in `part`.
    fn pick_cores(
        &mut self,
        index: &FreeIndex,
        cluster: &Cluster,
        part: u32,
        cores: u32,
        mem_mib: u64,
    ) -> Option<NodeId>;

    /// Pick a wholly idle node for a whole-node request in `part`.
    fn pick_whole(&mut self, index: &FreeIndex, cluster: &Cluster, part: u32) -> Option<NodeId>;
}

/// Lowest-numbered node that fits — the indexed equivalent of the
/// historical linear scan (identical choices, without the O(N) walk).
#[derive(Debug, Default)]
pub struct FirstFit;

impl PlacementPolicy for FirstFit {
    fn strategy(&self) -> Strategy {
        Strategy::FirstFit
    }

    fn pick_cores(
        &mut self,
        index: &FreeIndex,
        cluster: &Cluster,
        part: u32,
        cores: u32,
        mem_mib: u64,
    ) -> Option<NodeId> {
        index.first_fit(cluster, part, cores, mem_mib)
    }

    fn pick_whole(&mut self, index: &FreeIndex, cluster: &Cluster, part: u32) -> Option<NodeId> {
        index.idle_lowest(cluster, part)
    }
}

/// Fewest sufficient free cores — packs partial nodes densely, keeping
/// whole nodes free for node-level jobs.
#[derive(Debug, Default)]
pub struct BestFit;

impl PlacementPolicy for BestFit {
    fn strategy(&self) -> Strategy {
        Strategy::BestFit
    }

    fn pick_cores(
        &mut self,
        index: &FreeIndex,
        cluster: &Cluster,
        part: u32,
        cores: u32,
        mem_mib: u64,
    ) -> Option<NodeId> {
        index.best_fit(cluster, part, cores, mem_mib)
    }

    fn pick_whole(&mut self, index: &FreeIndex, cluster: &Cluster, part: u32) -> Option<NodeId> {
        index.idle_lowest(cluster, part)
    }
}

/// Most free cores (worst-fit) — spreads load across the machine.
#[derive(Debug, Default)]
pub struct Spread;

impl PlacementPolicy for Spread {
    fn strategy(&self) -> Strategy {
        Strategy::Spread
    }

    fn pick_cores(
        &mut self,
        index: &FreeIndex,
        cluster: &Cluster,
        part: u32,
        cores: u32,
        mem_mib: u64,
    ) -> Option<NodeId> {
        index.worst_fit(cluster, part, cores, mem_mib)
    }

    fn pick_whole(&mut self, index: &FreeIndex, cluster: &Cluster, part: u32) -> Option<NodeId> {
        index.idle_lowest(cluster, part)
    }
}

/// Uniformly random fitting node (seeded, so runs stay reproducible).
#[derive(Debug)]
pub struct Random {
    rng: Rng,
}

impl Random {
    pub fn new(seed: u64) -> Random {
        Random { rng: Rng::new(seed) }
    }
}

impl PlacementPolicy for Random {
    fn strategy(&self) -> Strategy {
        Strategy::Random
    }

    fn pick_cores(
        &mut self,
        index: &FreeIndex,
        cluster: &Cluster,
        part: u32,
        cores: u32,
        mem_mib: u64,
    ) -> Option<NodeId> {
        index.random_fit(cluster, part, cores, mem_mib, &mut self.rng)
    }

    fn pick_whole(&mut self, index: &FreeIndex, cluster: &Cluster, part: u32) -> Option<NodeId> {
        index.idle_random(cluster, part, &mut self.rng)
    }
}

/// The paper's node-based fast path: whole-node requests pop straight
/// off one end of the idle pool (O(log n), no ordering work); stray
/// core-level requests fall back to indexed best-fit so they pack into
/// partial nodes instead of breaking idle ones.
#[derive(Debug, Default)]
pub struct NodeBasedFast;

impl PlacementPolicy for NodeBasedFast {
    fn strategy(&self) -> Strategy {
        Strategy::NodeBased
    }

    fn pick_cores(
        &mut self,
        index: &FreeIndex,
        cluster: &Cluster,
        part: u32,
        cores: u32,
        mem_mib: u64,
    ) -> Option<NodeId> {
        index.best_fit(cluster, part, cores, mem_mib)
    }

    fn pick_whole(&mut self, index: &FreeIndex, cluster: &Cluster, part: u32) -> Option<NodeId> {
        index.idle_highest(cluster, part)
    }
}

/// Construct the policy for a strategy. `seed` only feeds the random
/// policy's generator; deterministic policies ignore it.
pub fn policy_for(strategy: Strategy, seed: u64) -> Box<dyn PlacementPolicy> {
    match strategy {
        Strategy::FirstFit => Box::new(FirstFit),
        Strategy::BestFit => Box::new(BestFit),
        Strategy::Spread => Box::new(Spread),
        Strategy::Random => Box::new(Random::new(seed)),
        Strategy::NodeBased => Box::new(NodeBasedFast),
    }
}

/// The placement façade the scheduler dispatches through: owns the
/// index and policy, and pairs every cluster allocate/release with the
/// corresponding index delta.
pub struct PlacementEngine {
    index: FreeIndex,
    policy: Box<dyn PlacementPolicy>,
    seed: u64,
}

impl PlacementEngine {
    /// New engine over the cluster's current state.
    pub fn new(cluster: &Cluster, strategy: Strategy, seed: u64) -> PlacementEngine {
        PlacementEngine {
            index: FreeIndex::build(cluster),
            policy: policy_for(strategy, seed),
            seed,
        }
    }

    /// The active strategy.
    pub fn strategy(&self) -> Strategy {
        self.policy.strategy()
    }

    /// Swap the placement strategy (resets the random policy's stream).
    pub fn set_strategy(&mut self, strategy: Strategy) {
        self.policy = policy_for(strategy, self.seed);
    }

    /// Rebuild the index from scratch — an escape hatch for callers
    /// that mutate the cluster (reservations, node states) outside the
    /// engine after construction. The scheduler never needs it: the
    /// cluster moves into the sim before the engine is built and every
    /// subsequent mutation flows through the engine.
    pub fn rebuild(&mut self, cluster: &Cluster) {
        self.index = FreeIndex::build(cluster);
    }

    /// Read access to the index (diagnostics, tests, benches).
    pub fn index(&self) -> &FreeIndex {
        &self.index
    }

    /// Place a whole-node request: pick an idle node via the policy,
    /// allocate every core and all free memory, update the index.
    pub fn place_whole(
        &mut self,
        cluster: &mut Cluster,
        reservation: Option<&str>,
    ) -> Option<Placement> {
        let part = self.index.partition_for(reservation)?;
        let node = self.policy.pick_whole(&self.index, cluster, part)?;
        let mem_mib = cluster.node(node).ok()?.free_mem_mib();
        let mask = cluster.node_mut(node).ok()?.allocate_whole().ok()?;
        self.index.on_delta(node, 0);
        Some(Placement { node, mask, mem_mib })
    }

    /// Place a `cores` + `mem_mib` request via the policy; allocate the
    /// lowest free cores on the chosen node, update the index.
    pub fn place_cores(
        &mut self,
        cluster: &mut Cluster,
        cores: u32,
        mem_mib: u64,
        reservation: Option<&str>,
    ) -> Option<Placement> {
        let part = self.index.partition_for(reservation)?;
        let node = self
            .policy
            .pick_cores(&self.index, cluster, part, cores, mem_mib)?;
        let mask = cluster.allocate_on(node, cores, mem_mib).ok()?;
        let free = cluster.node(node).ok()?.free_cores();
        self.index.on_delta(node, free);
        Some(Placement { node, mask, mem_mib })
    }

    /// Place a whole-node request on an idle node passing `allow`
    /// (lowest admissible id). Used while a backfill hold is active:
    /// other whole-node jobs must not take the held node, so the
    /// policy's unfiltered idle query is bypassed.
    pub fn place_whole_where(
        &mut self,
        cluster: &mut Cluster,
        reservation: Option<&str>,
        allow: &dyn Fn(NodeId) -> bool,
    ) -> Option<Placement> {
        let part = self.index.partition_for(reservation)?;
        let node = self.index.idle_lowest_where(cluster, part, allow)?;
        let mem_mib = cluster.node(node).ok()?.free_mem_mib();
        let mask = cluster.node_mut(node).ok()?.allocate_whole().ok()?;
        self.index.on_delta(node, 0);
        Some(Placement { node, mask, mem_mib })
    }

    /// Place a `cores` + `mem_mib` request on the tightest node passing
    /// `allow` (best-fit among admissible nodes). Backfill placements
    /// go through here so they pack into gaps instead of breaking idle
    /// nodes a reservation may be counting on.
    pub fn place_cores_where(
        &mut self,
        cluster: &mut Cluster,
        cores: u32,
        mem_mib: u64,
        reservation: Option<&str>,
        allow: &dyn Fn(NodeId) -> bool,
    ) -> Option<Placement> {
        let part = self.index.partition_for(reservation)?;
        let node = self.index.best_fit_where(cluster, part, cores, mem_mib, allow)?;
        let mask = cluster.allocate_on(node, cores, mem_mib).ok()?;
        let free = cluster.node(node).ok()?.free_cores();
        self.index.on_delta(node, free);
        Some(Placement { node, mask, mem_mib })
    }

    /// Would a filtered core placement succeed right now? Pure query —
    /// the dispatch loop's backfill-candidate test (no allocation).
    pub fn peek_cores_where(
        &self,
        cluster: &Cluster,
        reservation: Option<&str>,
        cores: u32,
        mem_mib: u64,
        allow: &dyn Fn(NodeId) -> bool,
    ) -> Option<NodeId> {
        let part = self.index.partition_for(reservation)?;
        self.index.best_fit_where(cluster, part, cores, mem_mib, allow)
    }

    /// Release a placement and update the index.
    pub fn release(&mut self, cluster: &mut Cluster, p: &Placement) -> Result<()> {
        cluster.release_on(p.node, &p.mask, p.mem_mib)?;
        let free = cluster.node(p.node)?.free_cores();
        self.index.on_delta(p.node, free);
        Ok(())
    }

    /// Flip a node's lifecycle state and keep the index in sync: a
    /// non-`Up` node leaves the fit-query buckets at once, a recovering
    /// node re-enters with its cached free count (allocations survive a
    /// state flip, so the cache is still correct). This is the fault
    /// layer's fencing primitive. Returns `false` for an unknown node.
    pub fn set_node_state(&mut self, cluster: &mut Cluster, node: NodeId, state: NodeState) -> bool {
        let Ok(n) = cluster.node_mut(node) else {
            return false;
        };
        n.set_state(state);
        self.index.on_state_change(node, state);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::ALL_STRATEGIES;

    #[test]
    fn factory_maps_strategies() {
        for s in ALL_STRATEGIES {
            assert_eq!(policy_for(s, 1).strategy(), s);
        }
    }

    #[test]
    fn engine_round_trips_whole_nodes() {
        let mut c = Cluster::tx_green(3);
        let mut e = PlacementEngine::new(&c, Strategy::NodeBased, 1);
        let a = e.place_whole(&mut c, None).expect("idle node");
        let b = e.place_whole(&mut c, None).expect("second idle node");
        assert_ne!(a.node, b.node);
        assert_eq!(c.busy_cores(), 2 * 64);
        e.index().check_consistency(&c).unwrap();
        e.release(&mut c, &a).unwrap();
        e.release(&mut c, &b).unwrap();
        assert_eq!(c.busy_cores(), 0);
        e.index().check_consistency(&c).unwrap();
        // Three placements drain the cluster; a fourth fails cleanly.
        for _ in 0..3 {
            e.place_whole(&mut c, None).expect("refilled");
        }
        assert!(e.place_whole(&mut c, None).is_none());
        e.index().check_consistency(&c).unwrap();
    }

    #[test]
    fn engine_packs_core_requests() {
        let mut c = Cluster::tx_green(2);
        let mut e = PlacementEngine::new(&c, Strategy::BestFit, 1);
        let first = e.place_cores(&mut c, 10, 0, None).expect("fits");
        // Best-fit keeps stacking onto the already-broken node.
        let second = e.place_cores(&mut c, 10, 0, None).expect("fits");
        assert_eq!(first.node, second.node);
        e.index().check_consistency(&c).unwrap();
    }

    #[test]
    fn spread_breaks_fresh_nodes() {
        let mut c = Cluster::tx_green(2);
        let mut e = PlacementEngine::new(&c, Strategy::Spread, 1);
        let first = e.place_cores(&mut c, 10, 0, None).expect("fits");
        let second = e.place_cores(&mut c, 10, 0, None).expect("fits");
        assert_ne!(first.node, second.node, "worst-fit spreads");
    }

    #[test]
    fn first_fit_matches_scan_semantics() {
        let mut c = Cluster::tx_green(4);
        let mut e = PlacementEngine::new(&c, Strategy::FirstFit, 1);
        // Fill node 0, then ask again: first-fit walks to node 1, exactly
        // like Cluster::find_fit_node would.
        for _ in 0..64 {
            assert_eq!(e.place_cores(&mut c, 1, 0, None).unwrap().node, 0);
        }
        assert_eq!(e.place_cores(&mut c, 1, 0, None).unwrap().node, 1);
        assert_eq!(
            c.find_fit_node(1, 0, None),
            Some(1),
            "scan and index agree"
        );
    }

    #[test]
    fn filtered_placements_respect_allow() {
        let mut c = Cluster::tx_green(3);
        let mut e = PlacementEngine::new(&c, Strategy::NodeBased, 1);
        // Whole-node placement skips a disallowed (held) node.
        let p = e.place_whole_where(&mut c, None, &|n| n != 0).unwrap();
        assert_eq!(p.node, 1);
        // Core placement packs into the tightest admissible node.
        assert_eq!(e.peek_cores_where(&c, None, 4, 0, &|_| true), Some(0));
        let q = e.place_cores_where(&mut c, 4, 0, None, &|n| n == 2).unwrap();
        assert_eq!(q.node, 2);
        e.index().check_consistency(&c).unwrap();
        // Nothing admissible → clean None, no allocation.
        assert!(e.place_cores_where(&mut c, 1, 0, None, &|_| false).is_none());
        assert!(e.place_whole_where(&mut c, None, &|_| false).is_none());
        e.release(&mut c, &p).unwrap();
        e.release(&mut c, &q).unwrap();
        assert_eq!(c.busy_cores(), 0);
        e.index().check_consistency(&c).unwrap();
    }

    #[test]
    fn reservations_fence_engine_placements() {
        let mut c = Cluster::tx_green(4);
        c.reserve("bench", vec![2, 3]).unwrap();
        let mut e = PlacementEngine::new(&c, Strategy::FirstFit, 1);
        let open = e.place_whole(&mut c, None).unwrap();
        assert!(open.node < 2, "unreserved placement stays outside");
        let fenced = e.place_whole(&mut c, Some("bench")).unwrap();
        assert!(fenced.node >= 2, "reserved placement stays inside");
        assert!(e.place_whole(&mut c, Some("missing")).is_none());
    }
}
