//! Backfill reservations over the free-capacity index.
//!
//! The paper's motivating tension (and the "Best of Both Worlds" line
//! of work, arXiv:2008.02223) is interactive-vs-batch contention: large
//! whole-node jobs must not starve behind a stream of small core-level
//! jobs, and small jobs must not wait behind a blocked whole-node head.
//! The classic answer is EASY-style backfill: give the blocked
//! whole-node job an *earliest-start reservation* (a hold on the node
//! expected to free soonest), and let small jobs jump the queue only
//! when they provably vacate before the hold starts.
//!
//! [`ReservationLedger`] is the bookkeeping half of that policy. It
//! tracks, per node, the latest expected completion time among running
//! tasks (expected ends come from walltime *estimates* — exact in the
//! DES oracle case, noisy under a
//! [`crate::workload::contention::WalltimeError`] model), plans a hold
//! for a blocked whole-node task by picking the node with the earliest
//! expected free time from the [`FreeIndex`] partition, and answers the
//! admission question "may a task expected to end at `t` run on node
//! `n`?". The scheduler's dispatch loop ([`crate::scheduler::server`])
//! consults it both for backfill candidates and for normal core-level
//! placements while holds are active, so no later job — backfilled or
//! not — can delay a reservation's start.
//!
//! Since PR 3 the ledger carries up to `K` simultaneous holds
//! ([`ReservationLedger::set_max_holds`]): reservations for the top-K
//! blocked whole-node tasks, each fencing a distinct node. `K = 1`
//! reproduces the original EASY single-hold discipline exactly.

use crate::cluster::{Cluster, NodeId, NodeState};
use crate::placement::free_index::FreeIndex;
use crate::scheduler::job::TaskId;
use crate::sim::Time;

/// Slack added to hold starts when admitting work onto a held node:
/// a task may end exactly at the hold start (the hold task dispatches
/// after the freeing cleanup anyway), so exact ties are admissible.
const TIE_EPS: Time = 1e-9;

/// An earliest-start reservation for one blocked whole-node task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hold {
    /// The whole-node scheduling task the hold protects.
    pub task: TaskId,
    /// The node expected to free soonest when the hold was planned.
    pub node: NodeId,
    /// Expected start time: when `node`'s last running task ends.
    pub start: Time,
}

/// Per-node expected-completion bookkeeping plus the active holds.
///
/// At most [`Self::max_holds`] reservations at a time, on pairwise
/// distinct nodes; holds beyond that would shrink backfill opportunity
/// without improving the starvation bound the property tests pin down.
#[derive(Debug, Clone)]
pub struct ReservationLedger {
    /// Node → latest expected occupancy end among running tasks.
    expected_end: Vec<Time>,
    /// Node → number of running tasks (resets `expected_end` at zero).
    running: Vec<u32>,
    /// Active holds, in planning order. Invariants: `len() ≤ max_holds`,
    /// one hold per task, one hold per node.
    holds: Vec<Hold>,
    max_holds: usize,
}

impl ReservationLedger {
    /// Ledger over `n_nodes` nodes, all initially idle. Starts in the
    /// single-hold (EASY) discipline; raise via [`Self::set_max_holds`].
    pub fn new(n_nodes: usize) -> ReservationLedger {
        ReservationLedger {
            expected_end: vec![0.0; n_nodes],
            running: vec![0; n_nodes],
            holds: Vec::new(),
            max_holds: 1,
        }
    }

    /// Allow up to `k` simultaneous holds (clamped to ≥ 1). Shrinking
    /// drops the most recently planned holds first.
    pub fn set_max_holds(&mut self, k: usize) {
        self.max_holds = k.max(1);
        self.holds.truncate(self.max_holds);
    }

    /// The configured hold capacity K.
    pub fn max_holds(&self) -> usize {
        self.max_holds
    }

    /// A task was placed on `node` with an (estimated) occupancy end.
    pub fn note_start(&mut self, node: NodeId, expected_end: Time) {
        let i = node as usize;
        self.running[i] += 1;
        if expected_end > self.expected_end[i] {
            self.expected_end[i] = expected_end;
        }
    }

    /// A task's resources on `node` were released (cleanup finished).
    pub fn note_release(&mut self, node: NodeId) {
        let i = node as usize;
        self.running[i] = self.running[i].saturating_sub(1);
        if self.running[i] == 0 {
            self.expected_end[i] = 0.0;
        }
    }

    /// Expected time `node` frees relative to `now` (now if idle).
    pub fn expected_free(&self, node: NodeId, now: Time) -> Time {
        self.expected_end[node as usize].max(now)
    }

    /// All active holds, in planning order.
    pub fn holds(&self) -> &[Hold] {
        &self.holds
    }

    /// The oldest active hold, if any (single-hold-era accessor).
    pub fn hold(&self) -> Option<Hold> {
        self.holds.first().copied()
    }

    /// Whether any hold is active.
    pub fn has_holds(&self) -> bool {
        !self.holds.is_empty()
    }

    /// Whether the ledger is at its hold capacity.
    pub fn is_full(&self) -> bool {
        self.holds.len() >= self.max_holds
    }

    /// The active hold belonging to `task`, if any.
    pub fn hold_for(&self, task: TaskId) -> Option<Hold> {
        self.holds.iter().copied().find(|h| h.task == task)
    }

    /// The active hold fencing `node`, if any.
    pub fn hold_on(&self, node: NodeId) -> Option<Hold> {
        self.holds.iter().copied().find(|h| h.node == node)
    }

    /// Plan a hold for the blocked whole-node task `for_task`: the `Up`
    /// node of the partition with the earliest expected free time
    /// (lowest id on ties), skipping nodes already fenced for *other*
    /// tasks. O(partition) — runs on head-of-line block, not dispatch.
    pub fn plan_whole_node(
        &self,
        index: &FreeIndex,
        cluster: &Cluster,
        part: u32,
        now: Time,
        for_task: TaskId,
    ) -> Option<(NodeId, Time)> {
        self.plan_whole_node_where(index, cluster, part, now, for_task, &|_| true)
    }

    /// [`Self::plan_whole_node`] restricted to nodes passing `allow` —
    /// a hold must never be planted on a node the batch scheduler has
    /// ceded (e.g. one leased to the rapid-launch pool, which looks
    /// idle to the index but will never serve the reservation).
    pub fn plan_whole_node_where(
        &self,
        index: &FreeIndex,
        cluster: &Cluster,
        part: u32,
        now: Time,
        for_task: TaskId,
        allow: &dyn Fn(NodeId) -> bool,
    ) -> Option<(NodeId, Time)> {
        let mut best: Option<(NodeId, Time)> = None;
        for id in index.partition_nodes_iter(part) {
            if !allow(id) {
                continue;
            }
            let up = cluster
                .node(id)
                .map(|n| n.state() == NodeState::Up)
                .unwrap_or(false);
            if !up {
                continue;
            }
            if self.holds.iter().any(|h| h.node == id && h.task != for_task) {
                continue;
            }
            let free_at = self.expected_free(id, now);
            let better = match best {
                None => true,
                Some((_, t)) => free_at < t,
            };
            if better {
                best = Some((id, free_at));
            }
        }
        best
    }

    /// Install (or refresh) the hold for `task`. Refused when the
    /// ledger is at capacity with other tasks' holds, or when `node` is
    /// already fenced for a different task — holds never overlap.
    pub fn set_hold(&mut self, task: TaskId, node: NodeId, start: Time) -> bool {
        if self.holds.iter().any(|h| h.task != task && h.node == node) {
            return false;
        }
        if let Some(i) = self.holds.iter().position(|h| h.task == task) {
            self.holds[i] = Hold { task, node, start };
            return true;
        }
        if self.holds.len() >= self.max_holds {
            return false;
        }
        self.holds.push(Hold { task, node, start });
        true
    }

    /// Drop the hold belonging to `task` (placement succeeded or the
    /// task was cancelled/preempted). Other tasks' holds are untouched.
    pub fn clear_hold(&mut self, task: TaskId) {
        self.holds.retain(|h| h.task != task);
    }

    /// May a task expected to end at `est_end` be placed on `node`
    /// without delaying any active hold? Unheld nodes are always
    /// admissible (their occupancy cannot move a held node's free
    /// time); a held node admits only tasks that vacate first.
    pub fn allows_backfill(&self, node: NodeId, est_end: Time) -> bool {
        match self.hold_on(node) {
            None => true,
            Some(h) => est_end <= h.start + TIE_EPS,
        }
    }

    /// May a whole-node task other than a hold's own take `node`?
    /// While a hold is active, its node is fenced off for it.
    pub fn allows_whole_node(&self, node: NodeId, task: TaskId) -> bool {
        match self.hold_on(node) {
            None => true,
            Some(h) => h.task == task,
        }
    }

    /// Structural invariants the property harness pins down: at most K
    /// holds, one per task, one per node, all nodes in range.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.holds.len() > self.max_holds {
            return Err(format!(
                "{} holds exceed capacity {}",
                self.holds.len(),
                self.max_holds
            ));
        }
        for (i, a) in self.holds.iter().enumerate() {
            if a.node as usize >= self.expected_end.len() {
                return Err(format!("hold on unknown node {}", a.node));
            }
            for b in &self.holds[i + 1..] {
                if a.node == b.node {
                    return Err(format!(
                        "holds for tasks {} and {} overlap on node {}",
                        a.task, b.task, a.node
                    ));
                }
                if a.task == b.task {
                    return Err(format!("task {} holds two nodes", a.task));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    #[test]
    fn start_release_tracks_expected_ends() {
        let mut l = ReservationLedger::new(3);
        l.note_start(1, 50.0);
        l.note_start(1, 30.0);
        assert_eq!(l.expected_free(1, 10.0), 50.0);
        assert_eq!(l.expected_free(0, 10.0), 10.0, "idle node frees now");
        l.note_release(1);
        assert_eq!(l.expected_free(1, 10.0), 50.0, "one task still running");
        l.note_release(1);
        assert_eq!(l.expected_free(1, 10.0), 10.0, "empty node resets");
    }

    #[test]
    fn plan_picks_earliest_freeing_node() {
        let c = Cluster::tx_green(3);
        let idx = FreeIndex::build(&c);
        let mut l = ReservationLedger::new(3);
        l.note_start(0, 100.0);
        l.note_start(1, 40.0);
        l.note_start(2, 70.0);
        assert_eq!(l.plan_whole_node(&idx, &c, 0, 5.0, 9), Some((1, 40.0)));
        // An already-idle node frees "now" and wins.
        l.note_release(1);
        assert_eq!(l.plan_whole_node(&idx, &c, 0, 5.0, 9), Some((1, 5.0)));
    }

    #[test]
    fn plan_skips_down_nodes() {
        let mut c = Cluster::tx_green(2);
        let mut idx = FreeIndex::build(&c);
        c.node_mut(0).unwrap().set_state(NodeState::Down);
        idx.on_state_change(0, NodeState::Down);
        let l = ReservationLedger::new(2);
        assert_eq!(l.plan_whole_node(&idx, &c, 0, 0.0, 9), Some((1, 0.0)));
    }

    #[test]
    fn plan_where_respects_allow() {
        let c = Cluster::tx_green(3);
        let idx = FreeIndex::build(&c);
        let mut l = ReservationLedger::new(3);
        l.note_start(0, 100.0);
        l.note_start(1, 40.0);
        l.note_start(2, 70.0);
        // The earliest-freeing node (1) is fenced off (e.g. pool-leased):
        // planning falls through to the next-earliest admissible node.
        assert_eq!(
            l.plan_whole_node_where(&idx, &c, 0, 5.0, 9, &|n| n != 1),
            Some((2, 70.0))
        );
        assert_eq!(l.plan_whole_node_where(&idx, &c, 0, 5.0, 9, &|_| false), None);
        // The unfiltered wrapper matches an always-true filter.
        assert_eq!(
            l.plan_whole_node(&idx, &c, 0, 5.0, 9),
            l.plan_whole_node_where(&idx, &c, 0, 5.0, 9, &|_| true)
        );
    }

    #[test]
    fn plan_skips_nodes_held_for_other_tasks() {
        let c = Cluster::tx_green(3);
        let idx = FreeIndex::build(&c);
        let mut l = ReservationLedger::new(3);
        l.set_max_holds(3);
        l.note_start(0, 100.0);
        l.note_start(1, 40.0);
        l.note_start(2, 70.0);
        assert!(l.set_hold(7, 1, 40.0), "task 7 takes the earliest node");
        // Task 8 must plan around node 1; next-earliest is node 2.
        assert_eq!(l.plan_whole_node(&idx, &c, 0, 5.0, 8), Some((2, 70.0)));
        // Re-planning for the holder itself may keep its own node.
        assert_eq!(l.plan_whole_node(&idx, &c, 0, 5.0, 7), Some((1, 40.0)));
    }

    #[test]
    fn single_hold_discipline() {
        let mut l = ReservationLedger::new(2);
        assert!(l.set_hold(7, 0, 100.0));
        assert!(!l.set_hold(8, 1, 50.0), "second hold refused at K = 1");
        assert!(l.set_hold(7, 1, 90.0), "own hold refreshes");
        assert_eq!(l.hold_for(7).unwrap().start, 90.0);
        assert!(l.hold_for(8).is_none());
        l.clear_hold(8);
        assert!(l.hold().is_some(), "other task cannot clear");
        l.clear_hold(7);
        assert!(l.hold().is_none());
        assert!(l.set_hold(8, 1, 50.0), "free again");
    }

    #[test]
    fn multi_hold_discipline() {
        let mut l = ReservationLedger::new(4);
        l.set_max_holds(3);
        assert!(l.set_hold(1, 0, 10.0));
        assert!(l.set_hold(2, 1, 20.0));
        assert!(l.set_hold(3, 2, 30.0));
        assert!(l.is_full());
        assert!(!l.set_hold(4, 3, 40.0), "fourth hold refused at K = 3");
        assert_eq!(l.holds().len(), 3);
        // Distinct-node discipline: nobody may fence an already-held node.
        assert!(!l.set_hold(2, 0, 5.0), "refresh onto another task's node refused");
        assert!(l.set_hold(2, 3, 25.0), "refresh onto a free node ok");
        assert_eq!(l.hold_for(2).unwrap().node, 3);
        // Clearing one hold frees exactly one slot and its node.
        l.clear_hold(2);
        assert_eq!(l.holds().len(), 2);
        assert!(l.hold_on(3).is_none());
        assert!(l.set_hold(4, 3, 40.0));
        assert!(l.check_invariants().is_ok());
    }

    #[test]
    fn shrinking_capacity_truncates_holds() {
        let mut l = ReservationLedger::new(4);
        l.set_max_holds(3);
        assert!(l.set_hold(1, 0, 10.0));
        assert!(l.set_hold(2, 1, 20.0));
        assert!(l.set_hold(3, 2, 30.0));
        l.set_max_holds(1);
        assert_eq!(l.holds().len(), 1, "newest holds dropped first");
        assert_eq!(l.hold().unwrap().task, 1);
        assert!(l.check_invariants().is_ok());
    }

    #[test]
    fn backfill_admission_rules() {
        let mut l = ReservationLedger::new(3);
        assert!(l.allows_backfill(0, 1e12), "no hold: anything goes");
        l.set_hold(1, 2, 100.0);
        assert!(l.allows_backfill(0, 1e12), "unheld node unrestricted");
        assert!(l.allows_backfill(2, 99.0), "vacates before the hold");
        assert!(l.allows_backfill(2, 100.0), "exact tie admissible");
        assert!(!l.allows_backfill(2, 101.0), "would delay the hold");
        assert!(l.allows_whole_node(2, 1), "hold task may take its node");
        assert!(!l.allows_whole_node(2, 9), "others may not");
        assert!(l.allows_whole_node(0, 9));
    }

    #[test]
    fn admission_checks_every_active_hold() {
        let mut l = ReservationLedger::new(4);
        l.set_max_holds(2);
        l.set_hold(1, 0, 50.0);
        l.set_hold(2, 3, 200.0);
        assert!(!l.allows_backfill(0, 60.0), "first hold enforced");
        assert!(l.allows_backfill(0, 50.0));
        assert!(!l.allows_backfill(3, 201.0), "second hold enforced too");
        assert!(l.allows_backfill(3, 150.0));
        assert!(l.allows_backfill(1, 1e12), "unheld nodes stay open");
        assert!(!l.allows_whole_node(0, 2), "fences are per-task");
        assert!(l.allows_whole_node(0, 1));
        assert!(l.allows_whole_node(3, 2));
    }
}
