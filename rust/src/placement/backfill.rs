//! Backfill reservations over the free-capacity index.
//!
//! The paper's motivating tension (and the "Best of Both Worlds" line
//! of work, arXiv:2008.02223) is interactive-vs-batch contention: large
//! whole-node jobs must not starve behind a stream of small core-level
//! jobs, and small jobs must not wait behind a blocked whole-node head.
//! The classic answer is EASY-style backfill: give the blocked
//! whole-node job an *earliest-start reservation* (a hold on the node
//! expected to free soonest), and let small jobs jump the queue only
//! when they provably vacate before the hold starts.
//!
//! [`ReservationLedger`] is the bookkeeping half of that policy. It
//! tracks, per node, the latest expected completion time among running
//! tasks (expected ends are exact in the DES: occupancy is known at
//! placement time), plans a hold for a blocked whole-node task by
//! picking the node with the earliest expected free time from the
//! [`FreeIndex`] partition, and answers the admission question "may a
//! task expected to end at `t` run on node `n`?". The scheduler's
//! dispatch loop ([`crate::scheduler::server`]) consults it both for
//! backfill candidates and for normal core-level placements while a
//! hold is active, so no later job — backfilled or not — can delay the
//! reservation's start.

use crate::cluster::{Cluster, NodeId, NodeState};
use crate::placement::free_index::FreeIndex;
use crate::scheduler::job::TaskId;
use crate::sim::Time;

/// Slack added to hold starts when admitting work onto the held node:
/// a task may end exactly at the hold start (the hold task dispatches
/// after the freeing cleanup anyway), so exact ties are admissible.
const TIE_EPS: Time = 1e-9;

/// An earliest-start reservation for one blocked whole-node task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hold {
    /// The whole-node scheduling task the hold protects.
    pub task: TaskId,
    /// The node expected to free soonest when the hold was planned.
    pub node: NodeId,
    /// Expected start time: when `node`'s last running task ends.
    pub start: Time,
}

/// Per-node expected-completion bookkeeping plus the active hold.
///
/// One hold at a time (EASY backfill reserves for the queue head only);
/// holds for deeper queue entries would shrink backfill opportunity
/// without improving the starvation bound the property tests pin down.
#[derive(Debug, Clone)]
pub struct ReservationLedger {
    /// Node → latest expected occupancy end among running tasks.
    expected_end: Vec<Time>,
    /// Node → number of running tasks (resets `expected_end` at zero).
    running: Vec<u32>,
    hold: Option<Hold>,
}

impl ReservationLedger {
    /// Ledger over `n_nodes` nodes, all initially idle.
    pub fn new(n_nodes: usize) -> ReservationLedger {
        ReservationLedger {
            expected_end: vec![0.0; n_nodes],
            running: vec![0; n_nodes],
            hold: None,
        }
    }

    /// A task was placed on `node` with known occupancy end.
    pub fn note_start(&mut self, node: NodeId, expected_end: Time) {
        let i = node as usize;
        self.running[i] += 1;
        if expected_end > self.expected_end[i] {
            self.expected_end[i] = expected_end;
        }
    }

    /// A task's resources on `node` were released (cleanup finished).
    pub fn note_release(&mut self, node: NodeId) {
        let i = node as usize;
        self.running[i] = self.running[i].saturating_sub(1);
        if self.running[i] == 0 {
            self.expected_end[i] = 0.0;
        }
    }

    /// Expected time `node` frees relative to `now` (now if idle).
    pub fn expected_free(&self, node: NodeId, now: Time) -> Time {
        self.expected_end[node as usize].max(now)
    }

    /// The active hold, if any.
    pub fn hold(&self) -> Option<Hold> {
        self.hold
    }

    /// The active hold if it belongs to `task`.
    pub fn hold_for(&self, task: TaskId) -> Option<Hold> {
        self.hold.filter(|h| h.task == task)
    }

    /// Plan a hold for a blocked whole-node task: the `Up` node of the
    /// partition with the earliest expected free time (lowest id on
    /// ties). O(partition) — runs on head-of-line block, not dispatch.
    pub fn plan_whole_node(
        &self,
        index: &FreeIndex,
        cluster: &Cluster,
        part: u32,
        now: Time,
    ) -> Option<(NodeId, Time)> {
        let mut best: Option<(NodeId, Time)> = None;
        for id in index.partition_nodes(part) {
            let up = cluster
                .node(id)
                .map(|n| n.state() == NodeState::Up)
                .unwrap_or(false);
            if !up {
                continue;
            }
            let free_at = self.expected_free(id, now);
            let better = match best {
                None => true,
                Some((_, t)) => free_at < t,
            };
            if better {
                best = Some((id, free_at));
            }
        }
        best
    }

    /// Install (or refresh) the hold for `task`. Refused while a
    /// different task's hold is active — one reservation at a time.
    pub fn set_hold(&mut self, task: TaskId, node: NodeId, start: Time) -> bool {
        match self.hold {
            Some(h) if h.task != task => false,
            _ => {
                self.hold = Some(Hold { task, node, start });
                true
            }
        }
    }

    /// Drop the hold if it belongs to `task` (placement succeeded or
    /// the task was cancelled/preempted).
    pub fn clear_hold(&mut self, task: TaskId) {
        if self.hold.map(|h| h.task == task).unwrap_or(false) {
            self.hold = None;
        }
    }

    /// May a task expected to end at `est_end` be placed on `node`
    /// without delaying the active hold? Non-held nodes are always
    /// admissible (their occupancy cannot move the held node's free
    /// time); the held node admits only tasks that vacate first.
    pub fn allows_backfill(&self, node: NodeId, est_end: Time) -> bool {
        match self.hold {
            None => true,
            Some(h) => h.node != node || est_end <= h.start + TIE_EPS,
        }
    }

    /// May a whole-node task other than the hold's own take `node`?
    /// While a hold is active, the held node is fenced off for it.
    pub fn allows_whole_node(&self, node: NodeId, task: TaskId) -> bool {
        match self.hold {
            None => true,
            Some(h) => h.task == task || h.node != node,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    #[test]
    fn start_release_tracks_expected_ends() {
        let mut l = ReservationLedger::new(3);
        l.note_start(1, 50.0);
        l.note_start(1, 30.0);
        assert_eq!(l.expected_free(1, 10.0), 50.0);
        assert_eq!(l.expected_free(0, 10.0), 10.0, "idle node frees now");
        l.note_release(1);
        assert_eq!(l.expected_free(1, 10.0), 50.0, "one task still running");
        l.note_release(1);
        assert_eq!(l.expected_free(1, 10.0), 10.0, "empty node resets");
    }

    #[test]
    fn plan_picks_earliest_freeing_node() {
        let c = Cluster::tx_green(3);
        let idx = FreeIndex::build(&c);
        let mut l = ReservationLedger::new(3);
        l.note_start(0, 100.0);
        l.note_start(1, 40.0);
        l.note_start(2, 70.0);
        assert_eq!(l.plan_whole_node(&idx, &c, 0, 5.0), Some((1, 40.0)));
        // An already-idle node frees "now" and wins.
        l.note_release(1);
        assert_eq!(l.plan_whole_node(&idx, &c, 0, 5.0), Some((1, 5.0)));
    }

    #[test]
    fn plan_skips_down_nodes() {
        let mut c = Cluster::tx_green(2);
        let mut idx = FreeIndex::build(&c);
        c.node_mut(0).unwrap().set_state(NodeState::Down);
        idx.on_state_change(0, NodeState::Down);
        let l = ReservationLedger::new(2);
        assert_eq!(l.plan_whole_node(&idx, &c, 0, 0.0), Some((1, 0.0)));
    }

    #[test]
    fn single_hold_discipline() {
        let mut l = ReservationLedger::new(2);
        assert!(l.set_hold(7, 0, 100.0));
        assert!(!l.set_hold(8, 1, 50.0), "second hold refused");
        assert!(l.set_hold(7, 1, 90.0), "own hold refreshes");
        assert_eq!(l.hold_for(7).unwrap().start, 90.0);
        assert!(l.hold_for(8).is_none());
        l.clear_hold(8);
        assert!(l.hold().is_some(), "other task cannot clear");
        l.clear_hold(7);
        assert!(l.hold().is_none());
        assert!(l.set_hold(8, 1, 50.0), "free again");
    }

    #[test]
    fn backfill_admission_rules() {
        let mut l = ReservationLedger::new(3);
        assert!(l.allows_backfill(0, 1e12), "no hold: anything goes");
        l.set_hold(1, 2, 100.0);
        assert!(l.allows_backfill(0, 1e12), "non-held node unrestricted");
        assert!(l.allows_backfill(2, 99.0), "vacates before the hold");
        assert!(l.allows_backfill(2, 100.0), "exact tie admissible");
        assert!(!l.allows_backfill(2, 101.0), "would delay the hold");
        assert!(l.allows_whole_node(2, 1), "hold task may take its node");
        assert!(!l.allows_whole_node(2, 9), "others may not");
        assert!(l.allows_whole_node(0, 9));
    }
}
