//! Backfill invariants, end-to-end through the scheduler.
//!
//! Two properties pin the reservation machinery down:
//!
//! 1. **No delay**: no backfilled task placed on a held node may still
//!    be running when the hold's planned start arrives (checked from
//!    the recorded `BackfillEvent`s against the task records), and
//!    enabling backfill must not push a whole-node job's start
//!    materially later than the plain head-of-line discipline.
//! 2. **No starvation**: under sustained small-job pressure, whole-node
//!    jobs still run promptly — the earliest-start hold fences a
//!    draining node off from the backfill stream.

use llsched::cluster::Cluster;
use llsched::scheduler::core::{SchedulerSim, SimOutcome, TaskModel};
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::job::{
    ComputeBatch, JobSpec, ResourceRequest, SchedTaskSpec, TaskState,
};
use llsched::scheduler::noise::NoiseModel;
use llsched::sim::EventQueue;
use llsched::testing::prop::forall;

/// Quiet, deterministic sim: no noise, no jitter, unit server speed.
fn quiet_sim(nodes: u32, seed: u64, backfill: bool) -> SchedulerSim {
    SchedulerSim::new(
        Cluster::tx_green(nodes),
        CostModel::slurm_like_tx_green(),
        NoiseModel::dedicated(),
        seed,
    )
    .with_task_model(TaskModel {
        startup: 0.0,
        jitter_sigma: 0.0,
        p_node_late: 0.0,
        late_range: (0.0, 0.0),
    })
    .with_server_speed(1.0)
    .with_backfill(backfill)
}

fn job(
    name: &str,
    n_tasks: usize,
    request: ResourceRequest,
    duration: f64,
    priority: i32,
) -> JobSpec {
    let lanes = match request {
        ResourceRequest::WholeNode => 64,
        ResourceRequest::Cores { cores, .. } => cores,
    };
    JobSpec {
        name: name.into(),
        tasks: vec![
            SchedTaskSpec {
                request,
                duration,
                batch: ComputeBatch { count: 1, each: duration },
                lanes,
            };
            n_tasks
        ],
        reservation: None,
        priority,
        preemptable: false,
    }
}

/// Assert the recorded backfills respect the no-delay invariant.
fn assert_holds_respected(out: &SimOutcome) {
    for b in &out.backfills {
        let Some(h) = b.hold else { continue };
        if b.node != h.node {
            continue;
        }
        let end = out.records[b.task as usize]
            .end_t
            .expect("backfilled task ran");
        assert!(
            end <= h.start + 1e-6,
            "backfilled task {} on held node {} ends {} after hold start {}",
            b.task,
            b.node,
            end,
            h.start
        );
    }
}

// A crafted gap scenario: node 0 half-busy with a 50 s core job, node 1
// taken whole; a second whole-node task blocks and holds node 0 while
// short interactive tasks arrive — they must backfill into node 0's gap
// and vacate before the hold starts.
fn gap_scenario(backfill: bool) -> SimOutcome {
    let mut sim = quiet_sim(2, 9, backfill);
    let mut q = EventQueue::new();
    sim.submit_at(
        &mut q,
        0.0,
        job("warm", 1, ResourceRequest::Cores { cores: 32, mem_mib: 0 }, 50.0, 0),
    );
    sim.submit_at(&mut q, 1.0, job("batch", 2, ResourceRequest::WholeNode, 100.0, 0));
    sim.submit_at(
        &mut q,
        2.0,
        job("inter", 10, ResourceRequest::Cores { cores: 8, mem_mib: 0 }, 5.0, 5),
    );
    sim.run(&mut q)
}

#[test]
fn backfill_fills_gaps_and_vacates_before_the_hold() {
    let out = gap_scenario(true);
    assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    assert!(!out.backfills.is_empty(), "the gap scenario must backfill");
    assert_holds_respected(&out);
    // Interactive tasks ran well before the 50 s drain of node 0.
    let inter_starts: Vec<f64> = out
        .records
        .iter()
        .filter(|r| r.job == 2)
        .map(|r| r.start_t.unwrap())
        .collect();
    assert_eq!(inter_starts.len(), 10);
    assert!(
        inter_starts.iter().all(|&s| s < 45.0),
        "interactive starts {inter_starts:?} should beat the 50 s drain"
    );
}

#[test]
fn backfill_does_not_delay_whole_node_starts() {
    let with = gap_scenario(true);
    let without = gap_scenario(false);
    let last_batch_start = |out: &SimOutcome| -> f64 {
        out.records
            .iter()
            .filter(|r| r.job == 1)
            .map(|r| r.start_t.unwrap())
            .fold(0.0, f64::max)
    };
    let on = last_batch_start(&with);
    let off = last_batch_start(&without);
    // Generous server-op slack; a real regression (waiting out a 5 s
    // interactive wave, or worse) is an order of magnitude larger.
    assert!(
        on <= off + 5.0,
        "backfill delayed the whole-node job: {on} vs {off}"
    );
    // And the interactive class must have gained from backfill.
    let median_inter = |out: &SimOutcome| -> f64 {
        let mut lats: Vec<f64> = out
            .records
            .iter()
            .filter(|r| r.job == 2)
            .map(|r| r.start_t.unwrap() - r.submit_t)
            .collect();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        lats[lats.len() / 2]
    };
    assert!(
        median_inter(&with) + 10.0 < median_inter(&without),
        "backfill should cut interactive latency: {} vs {}",
        median_inter(&with),
        median_inter(&without)
    );
}

#[test]
fn whole_node_jobs_run_under_sustained_small_job_pressure() {
    // 4 nodes; an oversubscribing stream of 48-core 10 s tasks (arrays
    // of 5, every 5 s, for 300 s — only one fits per node, so nodes are
    // never wholly free while the stream has backlog) plus a trickle of
    // 8-core 2 s tasks that can backfill into the 16-core gaps. A
    // 2-task whole-node job submitted at t = 20 must still start
    // promptly: its hold fences a draining node off from the stream.
    let mut sim = quiet_sim(4, 13, true);
    let mut q = EventQueue::new();
    for i in 0..60u64 {
        sim.submit_at(
            &mut q,
            5.0 * i as f64,
            job("big", 5, ResourceRequest::Cores { cores: 48, mem_mib: 0 }, 10.0, 0),
        );
        sim.submit_at(
            &mut q,
            5.0 * i as f64 + 2.5,
            job("small", 5, ResourceRequest::Cores { cores: 8, mem_mib: 0 }, 2.0, 0),
        );
    }
    // Off the 2.5 s arrival grid so the submit does not land inside
    // another job's registration window (which would spin TICK retries).
    let batch = sim.submit_at(&mut q, 21.3, job("batch", 2, ResourceRequest::WholeNode, 30.0, 0));
    let out = sim.run(&mut q);
    assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    assert!(!out.backfills.is_empty(), "pressure scenario must backfill");
    assert_holds_respected(&out);
    let batch_starts: Vec<f64> = out
        .records
        .iter()
        .filter(|r| r.job == batch)
        .map(|r| r.start_t.unwrap())
        .collect();
    assert_eq!(batch_starts.len(), 2);
    for s in &batch_starts {
        assert!(
            *s < 150.0,
            "whole-node task starved until {s} under small-job pressure"
        );
    }
}

#[test]
fn backfilled_tasks_never_delay_reservations_under_random_mixes() {
    forall("backfill no-delay invariant", 25, |g| {
        let nodes = 2 + g.int(0, 4) as u32;
        let seed = g.int(0, u64::MAX - 1);
        let mut sim = quiet_sim(nodes, seed, true);
        let mut q = EventQueue::new();
        // One whole-node batch array somewhere in the arrival window.
        let batch_tasks = 1 + g.usize(1, nodes as usize * 2);
        let batch_at = g.f64(0.0, 20.0);
        sim.submit_at(
            &mut q,
            batch_at,
            job(
                "batch",
                batch_tasks,
                ResourceRequest::WholeNode,
                g.f64(20.0, 120.0),
                0,
            ),
        );
        // A fleet of small core jobs around it. Arrival times sit on a
        // fixed grid wider than the ~0.5 s registration window, so
        // submissions do not pile into TICK-granularity retry spins.
        let n_small = 5 + g.usize(0, 35);
        for i in 0..n_small {
            let cores = 1 << g.int(0, 5); // 1..32
            sim.submit_at(
                &mut q,
                1.0 + 1.25 * i as f64,
                job(
                    &format!("small-{i}"),
                    1 + g.usize(0, 3),
                    ResourceRequest::Cores { cores: cores as u32, mem_mib: 0 },
                    g.f64(1.0, 15.0),
                    g.int(0, 10) as i32,
                ),
            );
        }
        let out = sim.run(&mut q);
        if !out.records.iter().all(|r| r.state == TaskState::Done) {
            return Err("run did not drain".into());
        }
        for b in &out.backfills {
            let Some(h) = b.hold else { continue };
            if b.node != h.node {
                continue;
            }
            let end = out.records[b.task as usize]
                .end_t
                .ok_or("backfilled task has no end")?;
            if end > h.start + 1e-6 {
                return Err(format!(
                    "task {} on held node {} ends {} > hold start {}",
                    b.task, b.node, end, h.start
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn backfill_off_keeps_plain_head_of_line_semantics() {
    // With backfill disabled nothing may be recorded and the run must
    // behave exactly like the seed scheduler (strict head-of-line).
    let out = gap_scenario(false);
    assert!(out.backfills.is_empty());
    assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    // Strict HOL: no interactive task may start before the whole-node
    // head unblocks at the 50 s drain.
    let first_inter = out
        .records
        .iter()
        .filter(|r| r.job == 2)
        .map(|r| r.start_t.unwrap())
        .fold(f64::INFINITY, f64::min);
    assert!(
        first_inter >= 50.0,
        "without backfill interactive waits for the drain, got {first_inter}"
    );
}
