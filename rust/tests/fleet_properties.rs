//! Properties of the shape-sharded pool fleet, from the shard registry
//! up through the scheduler end-to-end.
//!
//! Five families of invariants pin the fleet down:
//!
//! 1. **Fleet-wide conservation** — under fuzzed multi-shard churn
//!    (lease/drain/promote/dispatch/release) *and* cross-shard
//!    rebalancing, every node is in exactly one shard or batch, every
//!    shard's own bookkeeping stays consistent, and borrows never
//!    create double ownership.
//! 2. **One-shard equivalence** — a one-shard fleet configured through
//!    the `pools = [...]` list syntax reproduces the legacy
//!    `pool_size`-keyed single pool bit-for-bit (same records, same
//!    event counts) across fuzzed seeds: the fleet layer adds nothing
//!    to the single-pool schedule.
//! 3. **No cross-shard leak** — end-to-end on the mixed-volley
//!    scenario, every task launched by a shard matches that shard's
//!    shape, no batch placement lands on any pooled node, and the
//!    conservation flag stays clean. A heterogeneous-cluster variant
//!    checks the capacity-class fence: a wide shard only ever serves
//!    its jobs from wide nodes.
//! 4. **Sharding wins** — on `burst_mixed` at 128 nodes, the two-shard
//!    fleet beats the equivalent single merged pool on p95 launch
//!    latency for *both* volley families (the acceptance regression):
//!    merged FIFO head-of-line-blocks whichever family arrives second,
//!    shard queues never do.
//! 5. **The PR 4 follow-up satellites** — pool-aware hold planning
//!    (a fully pool-fenced cluster still plans a hold, from the fleet's
//!    drain forecast) and drain-candidate selection by expected free
//!    time (the grow path drains the busy node that frees soonest, not
//!    the lowest id).

use llsched::cluster::{Cluster, NodeId};
use llsched::config::{parser, RunConfig};
use llsched::pool::{FleetConfig, JobShape, PoolConfig, PoolFleet, ShardConfig};
use llsched::scheduler::core::{SchedulerSim, SimOutcome, TaskModel};
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::job::{ComputeBatch, JobSpec, ResourceRequest, SchedTaskSpec, TaskState};
use llsched::scheduler::noise::NoiseModel;
use llsched::sim::EventQueue;
use llsched::testing::prop::forall;
use llsched::util::stats;
use llsched::workload::contention::{ContentionMix, JobClass};

fn quiet_sim_on(cluster: Cluster, seed: u64) -> SchedulerSim {
    SchedulerSim::new(
        cluster,
        CostModel::slurm_like_tx_green(),
        NoiseModel::dedicated(),
        seed,
    )
    .with_task_model(TaskModel {
        startup: 0.0,
        jitter_sigma: 0.0,
        p_node_late: 0.0,
        late_range: (0.0, 0.0),
    })
    .with_server_speed(1.0)
    .with_backfill(true)
}

fn quiet_sim(nodes: u32, seed: u64) -> SchedulerSim {
    quiet_sim_on(Cluster::tx_green(nodes), seed)
}

fn job(name: &str, n_tasks: usize, request: ResourceRequest, duration: f64, lanes: u32) -> JobSpec {
    JobSpec {
        name: name.into(),
        tasks: vec![
            SchedTaskSpec {
                request,
                duration,
                batch: ComputeBatch { count: 1, each: duration },
                lanes,
            };
            n_tasks
        ],
        reservation: None,
        priority: 0,
        preemptable: false,
    }
}

/// Property 1: fleet-wide conservation under fuzzed multi-shard churn
/// and rebalancing, applied the way the scheduler applies it (borrow →
/// lease idle → drain busy; shrink from the free list, else cancel
/// drains).
#[test]
fn conservation_under_fuzzed_multi_shard_churn() {
    forall("fleet conservation under churn", 40, |g| {
        let n = 3 + g.usize(0, 29);
        // Mixed capacities so the capacity-class fence is exercised.
        let capacity: Vec<u32> = (0..n).map(|i| if i % 3 == 0 { 128 } else { 64 }).collect();
        let shard = |name: &str, g: &mut llsched::testing::prop::Gen| {
            let size = 1 + g.usize(0, 3);
            let max = size + g.usize(0, n);
            ShardConfig::named(name, size, g.usize(0, size), max).unwrap()
        };
        let cfg = FleetConfig {
            shards: vec![shard("general", g), shard("large", g)],
        };
        cfg.validate().map_err(|e| format!("cfg invalid: {e}"))?;
        let mut fleet = PoolFleet::new(capacity.clone(), &cfg);
        // Random cluster occupancy decides lease-vs-drain below.
        let cluster_busy: Vec<bool> = (0..n).map(|_| g.chance(0.4)).collect();
        let mut queued = [g.usize(0, 20), g.usize(0, 20)];
        let mut busy: Vec<(usize, NodeId)> = Vec::new();
        for step in 0..200 {
            let sid = g.usize(0, 1);
            match g.usize(0, 6) {
                0 => queued[sid] = queued[sid].saturating_add(g.usize(0, 8)),
                1 => {
                    let sh = &mut fleet.shards[sid];
                    if let Some(node) = sh.dispatcher.launch(&mut sh.nodes) {
                        queued[sid] = queued[sid].saturating_sub(1);
                        fleet.note_launch(sid, node, step as f64 + 5.0, step as u64);
                        busy.push((sid, node));
                    }
                }
                2 => {
                    if !busy.is_empty() {
                        let (osid, node) = busy.remove(g.usize(0, busy.len() - 1));
                        let sh = &mut fleet.shards[osid];
                        if !sh.dispatcher.release(&mut sh.nodes, node) {
                            return Err(format!("step {step}: release of lease {node} refused"));
                        }
                        fleet.note_release(osid, node);
                    }
                }
                3 => {
                    if let Some(node) = fleet.shards[sid].nodes.any_draining() {
                        fleet.shards[sid].nodes.promote(node);
                    }
                }
                4 => {
                    fleet.borrow_into(sid, &|_| true);
                }
                _ => {
                    let decision = {
                        let sh = &fleet.shards[sid];
                        sh.manager.decide(
                            queued[sid],
                            sh.nodes.n_free(),
                            sh.nodes.n_leased(),
                            sh.nodes.n_draining(),
                        )
                    };
                    match decision {
                        llsched::pool::Resize::Grow(k) => {
                            for _ in 0..k {
                                if fleet.borrow_into(sid, &|_| true).is_some() {
                                    continue;
                                }
                                let shape = fleet.shards[sid].shape;
                                let cand = (0..n as NodeId).find(|&id| {
                                    !fleet.in_pool(id)
                                        && shape.node_fits(capacity[id as usize])
                                });
                                match cand {
                                    Some(id) => {
                                        if cluster_busy[id as usize] {
                                            fleet.shards[sid].nodes.begin_drain(id);
                                        } else {
                                            fleet.shards[sid].nodes.lease(id);
                                        }
                                    }
                                    None => break,
                                }
                            }
                        }
                        llsched::pool::Resize::Shrink(k) => {
                            for _ in 0..k {
                                if fleet.shards[sid].nodes.return_free().is_none() {
                                    if let Some(d) = fleet.shards[sid].nodes.any_draining() {
                                        fleet.shards[sid].nodes.cancel_drain(d);
                                    } else {
                                        break;
                                    }
                                }
                            }
                        }
                        llsched::pool::Resize::Hold => {}
                    }
                }
            }
            fleet
                .check_conservation()
                .map_err(|e| format!("step {step}: {e}"))?;
            let pooled: usize = fleet
                .shards
                .iter()
                .map(|s| s.nodes.n_leased() + s.nodes.n_draining())
                .sum();
            let batch = (0..n as NodeId).filter(|&id| !fleet.in_pool(id)).count();
            if pooled + batch != n {
                return Err(format!("step {step}: shards + batch do not partition the cluster"));
            }
            // The capacity-class fence: no shard owns a node too narrow
            // for its jobs.
            for sh in &fleet.shards {
                for id in 0..n as NodeId {
                    if sh.nodes.in_pool(id) && !sh.shape.node_fits(capacity[id as usize]) {
                        return Err(format!(
                            "step {step}: shard {} owns too-narrow node {id}",
                            sh.name
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Satellite regression: the node-indexed drain forecast (O(1) release)
/// holds exactly the same contents as the old `Vec<(NodeId, Time)>`
/// push/retain representation under fuzzed launch/release churn,
/// including re-launch overwrites, and the earliest-release estimate
/// agrees with a brute-force min over the reference list.
#[test]
fn drain_forecast_matches_reference_list_under_fuzzed_churn() {
    forall("node-indexed forecast equivalence", 60, |g| {
        let n = 2 + g.usize(0, 14);
        let cfg = FleetConfig {
            shards: vec![ShardConfig::named("general", 1, 0, n).unwrap()],
        };
        let mut fleet = PoolFleet::new(vec![64; n], &cfg);
        // Reference: the old representation, maintained the old way
        // (push on launch, retain on release).
        let mut reference: Vec<(NodeId, f64)> = Vec::new();
        // Lease and occupy every node so the shard has no free lease:
        // the release estimate then always reads the busy forecast.
        for id in 0..n as NodeId {
            assert!(fleet.shards[0].nodes.lease(id));
        }
        for _ in 0..n {
            assert!(fleet.shards[0].nodes.acquire().is_some());
        }
        let mut task = 0u64;
        for step in 0..300 {
            let node = g.usize(0, n - 1) as NodeId;
            if g.chance(0.55) {
                let est = step as f64 + g.f64(0.1, 50.0);
                // The old list never held two entries per node either —
                // a node relaunches only after its release — but an
                // overwrite must behave like retain-then-push.
                reference.retain(|&(m, _)| m != node);
                reference.push((node, est));
                fleet.note_launch(0, node, est, task);
                task += 1;
            } else {
                reference.retain(|&(m, _)| m != node);
                fleet.note_release(0, node);
            }
            let mut want = reference.clone();
            want.sort_by_key(|&(m, _)| m);
            let got = fleet.shards[0].busy_forecast();
            if got != want {
                return Err(format!("step {step}: forecast {got:?} != reference {want:?}"));
            }
            // The estimate agrees with a brute-force min over the
            // reference list (no free lease exists, so the busy
            // forecast is the only candidate source).
            let brute = reference
                .iter()
                .copied()
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .map(|(m, t)| (m, t.max(step as f64)));
            if fleet.earliest_release_estimate(step as f64) != brute {
                return Err(format!("step {step}: release estimate diverged"));
            }
            fleet.check_conservation().map_err(|e| format!("step {step}: {e}"))?;
        }
        Ok(())
    });
}

/// Property 2: a one-shard fleet written in the `pools = [...]` list
/// syntax schedules bit-for-bit like the legacy `pool_size` keys, from
/// config text all the way through the scheduler, across fuzzed
/// workloads and seeds.
#[test]
fn one_shard_fleet_matches_legacy_pool_keys_bit_for_bit() {
    forall("one-shard fleet equivalence", 10, |g| {
        let nodes = 2 + g.usize(0, 3) as u32;
        let seed = g.int(0, u64::MAX - 1);
        let size = 1 + g.usize(0, 2);
        let max = size + g.usize(0, 3);
        // The same elastic pool, written both ways. The list entry
        // reproduces the legacy shape (walltime ≤ 30 s, any lanes).
        let legacy = parser::parse(&format!(
            "[run]\npool_size = {size}\npool_min = 0\npool_max = {max}\n"
        ))
        .map_err(|e| e.to_string())?;
        let listed = parser::parse(&format!(
            "[run]\npools = [{{shape = \"short\", size = {size}, max = {max}}}]\n"
        ))
        .map_err(|e| e.to_string())?;
        let legacy_fleet = RunConfig::from_value(&legacy)
            .map_err(|e| e.to_string())?
            .fleet_config();
        let listed_fleet = RunConfig::from_value(&listed)
            .map_err(|e| e.to_string())?
            .fleet_config();
        if legacy_fleet.shards.len() != 1 || listed_fleet.shards.len() != 1 {
            return Err("both configs must resolve to one shard".into());
        }
        // A mixed workload: pool-eligible volleys, long whole-node
        // batch work, and core-level backfill bait.
        let mut subs: Vec<(f64, JobSpec)> = vec![
            (
                0.5,
                job("volley", 4 + g.usize(0, 12), ResourceRequest::WholeNode, g.f64(1.0, 20.0), 64),
            ),
            (
                1.0 + g.f64(0.0, 3.0),
                job("batch", 1 + g.usize(0, nodes as usize), ResourceRequest::WholeNode, g.f64(40.0, 80.0), 64),
            ),
        ];
        for i in 0..3 + g.usize(0, 6) {
            let cores = 1u32 << g.int(0, 4);
            subs.push((
                2.0 + i as f64,
                job(
                    &format!("small-{i}"),
                    1,
                    ResourceRequest::Cores { cores, mem_mib: 0 },
                    g.f64(1.0, 10.0),
                    cores,
                ),
            ));
        }
        let run = |fleet: FleetConfig| -> SimOutcome {
            let mut sim = quiet_sim(nodes, seed).with_fleet(fleet);
            let mut q = EventQueue::new();
            for (at, spec) in &subs {
                sim.submit_at(&mut q, *at, spec.clone());
            }
            sim.run(&mut q)
        };
        let a = run(legacy_fleet);
        let b = run(listed_fleet);
        if a.records.len() != b.records.len() {
            return Err("record count diverged".into());
        }
        for (x, y) in a.records.iter().zip(&b.records) {
            if x.state != y.state
                || x.start_t != y.start_t
                || x.end_t != y.end_t
                || x.cleanup_t != y.cleanup_t
                || x.cores != y.cores
            {
                return Err(format!("task {} diverged: {x:?} vs {y:?}", x.task));
            }
        }
        if a.events_processed != b.events_processed {
            return Err("event count diverged".into());
        }
        let (pa, pb) = (a.pool.expect("pool on"), b.pool.expect("pool on"));
        if pa.launches != pb.launches
            || pa.grows != pb.grows
            || pa.shrinks != pb.shrinks
            || pa.peak_leased != pb.peak_leased
        {
            return Err("pool accounting diverged".into());
        }
        if pa.invariant_violated || pb.invariant_violated {
            return Err("conservation broken".into());
        }
        Ok(())
    });
}

/// The shard configuration the acceptance regression uses at `nodes`:
/// a general rapid-launch shard and a large-capacity shard. Floors
/// equal the initial sizes so each family keeps a warm node set
/// between volleys — the floor doubles as the anti-poaching bound the
/// rebalancer respects, which is exactly what one merged FIFO cannot
/// provide (a large-first volley soaks the shared warm set and the
/// general wave starts cold).
fn two_shard_fleet(nodes: usize) -> FleetConfig {
    FleetConfig {
        shards: vec![
            ShardConfig {
                name: "general".into(),
                shape: JobShape::named("general").unwrap(),
                pool: PoolConfig {
                    size: nodes / 4,
                    min: nodes / 4,
                    max: nodes * 3 / 4,
                    ..PoolConfig::disabled()
                },
            },
            ShardConfig {
                name: "large".into(),
                shape: JobShape::named("large").unwrap(),
                pool: PoolConfig {
                    size: nodes / 16,
                    min: nodes / 16,
                    max: nodes / 4,
                    ..PoolConfig::disabled()
                },
            },
        ],
    }
}

/// The "equivalent single merged pool": one shard whose shape is the
/// union band and whose size/min/max are the shard sums (max clamped
/// to the machine).
fn merged_fleet(nodes: usize) -> FleetConfig {
    FleetConfig {
        shards: vec![ShardConfig {
            name: "merged".into(),
            shape: JobShape {
                min_lanes: 0,
                max_lanes: u32::MAX,
                min_walltime: 0.0,
                max_walltime: 60.0,
            },
            pool: PoolConfig {
                size: nodes / 4 + nodes / 16,
                min: nodes / 4 + nodes / 16,
                max: nodes,
                ..PoolConfig::disabled()
            },
        }],
    }
}

/// Run `burst_mixed` through the scheduler directly and split launch
/// latencies by volley family (job durations identify the family:
/// 0.5 s = general, 45 s = large).
fn run_mixed(nodes: u32, seed: u64, fleet: FleetConfig) -> (SimOutcome, Vec<f64>, Vec<f64>) {
    let mix = ContentionMix::preset("burst_mixed", nodes).unwrap();
    let subs = mix.generate(seed);
    let mut sim = quiet_sim(nodes, seed).with_fleet(fleet);
    let mut q = EventQueue::new();
    let mut durations: Vec<f64> = Vec::new();
    for sub in &subs {
        durations.push(sub.spec.tasks[0].duration);
        sim.submit_at(&mut q, sub.at, sub.spec.clone());
    }
    let out = sim.run(&mut q);
    let mut general = Vec::new();
    let mut large = Vec::new();
    for r in &out.records {
        let d = durations[r.job as usize];
        let Some(start) = r.start_t else { continue };
        let lat = start - r.submit_t;
        if (d - 0.5).abs() < 1e-9 {
            general.push(lat);
        } else if (d - 45.0).abs() < 1e-9 {
            large.push(lat);
        }
    }
    (out, general, large)
}

/// Property 3: no cross-shard leak on the mixed scenario — every shard
/// launch matches the shard's shape, the fleet conservation flag stays
/// clean, and both families drain.
#[test]
fn mixed_volleys_route_to_their_shards_without_leaks() {
    for seed in [3u64, 17, 29] {
        let nodes = 32u32;
        let (out, general, large) = run_mixed(nodes, seed, two_shard_fleet(nodes as usize));
        assert!(
            out.records.iter().all(|r| r.state == TaskState::Done),
            "seed {seed}: all tasks drain"
        );
        let pool = out.pool.as_ref().expect("fleet on");
        assert!(!pool.invariant_violated, "seed {seed}: conservation/fence broken");
        assert!(!out.hold_invariant_violated, "seed {seed}");
        assert_eq!(pool.shards.len(), 2);
        assert_eq!(
            pool.shards[0].launches as usize,
            general.len(),
            "seed {seed}: every general task went through the general shard"
        );
        assert_eq!(
            pool.shards[1].launches as usize,
            large.len(),
            "seed {seed}: every large task went through the large shard"
        );
        assert_eq!(
            pool.launches,
            pool.shards.iter().map(|s| s.launches).sum::<u64>()
        );
        // Batch stream stayed on the batch path (150 s > every shape):
        // exactly the volley tasks carry pool-launch tags, and the
        // fleet counter agrees with the per-record attribution.
        let tagged = out.records.iter().filter(|r| r.pool_shard.is_some()).count();
        assert_eq!(tagged, general.len() + large.len());
        assert_eq!(pool.launches as usize, tagged, "counter matches the record tags");
    }
}

/// Property 3b, capacity classes: on a heterogeneous cluster a wide
/// shard (min_lanes 65) serves its jobs from wide nodes only.
#[test]
fn wide_shard_only_leases_wide_nodes() {
    // Nodes 0-1: 128 cores; nodes 2-5: 64 cores.
    let cluster = Cluster::heterogeneous(&[(2, 128, 192 * 1024), (4, 64, 192 * 1024)]);
    let fleet = FleetConfig {
        shards: vec![
            ShardConfig::named("wide", 1, 1, 2).unwrap(),
            ShardConfig::named("general", 2, 1, 4).unwrap(),
        ],
    };
    let mut sim = quiet_sim_on(cluster, 7).with_fleet(fleet);
    let mut q = EventQueue::new();
    sim.submit_at(&mut q, 0.5, job("wide", 3, ResourceRequest::WholeNode, 0.5, 128));
    sim.submit_at(&mut q, 0.5, job("narrow", 6, ResourceRequest::WholeNode, 0.5, 64));
    let out = sim.run(&mut q);
    assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    let pool = out.pool.expect("fleet on");
    assert!(!pool.invariant_violated);
    assert_eq!(pool.shards[0].launches, 3, "wide jobs through the wide shard");
    assert_eq!(pool.shards[1].launches, 6, "narrow jobs through the general shard");
    // The capacity-class fence end-to-end: every wide launch ran on a
    // 128-core node (pool launches take the whole node). The wide
    // shard's launches are the records tagged with shard 0.
    let wide: Vec<_> = out.records.iter().filter(|r| r.pool_shard == Some(0)).collect();
    assert_eq!(wide.len(), 3);
    for r in wide {
        assert_eq!(r.cores, 128, "wide task {} ran on a narrow node", r.task);
    }
}

/// Property 4 + the acceptance regression: at 128 nodes, the two-shard
/// fleet strictly beats the equivalent single merged pool on p95 launch
/// latency for *both* volley families of `burst_mixed`. The mechanism:
/// the preset alternates which family is submitted first each round, so
/// one merged FIFO head-of-line-blocks the second family every round —
/// the general wave waits while larges soak the warm leases, and the
/// larges wait behind the whole general wave — while per-shard queues
/// and warm floors isolate both.
#[test]
fn sharded_fleet_beats_merged_pool_on_per_class_p95() {
    let nodes = 128u32;
    let seed = 11;
    let (sh_out, sh_general, sh_large) = run_mixed(nodes, seed, two_shard_fleet(nodes as usize));
    let (mg_out, mg_general, mg_large) = run_mixed(nodes, seed, merged_fleet(nodes as usize));
    for (label, out) in [("sharded", &sh_out), ("merged", &mg_out)] {
        assert!(
            out.records.iter().all(|r| r.state == TaskState::Done),
            "{label}: all tasks drain"
        );
        assert!(!out.pool.as_ref().unwrap().invariant_violated, "{label}");
    }
    assert_eq!(sh_general.len(), mg_general.len(), "same general population");
    assert_eq!(sh_large.len(), mg_large.len(), "same large population");
    let p95 = |xs: &[f64]| stats::percentile(xs, 95.0);
    let (sg, mg) = (p95(&sh_general), p95(&mg_general));
    let (sl, ml) = (p95(&sh_large), p95(&mg_large));
    assert!(
        sg < mg,
        "general p95: sharded {sg:.3}s must beat merged {mg:.3}s"
    );
    assert!(
        sl < ml,
        "large p95: sharded {sl:.3}s must beat merged {ml:.3}s"
    );
    // The fleet actually sharded the work.
    let pool = sh_out.pool.as_ref().unwrap();
    assert_eq!(pool.shards.len(), 2);
    assert!(pool.shards.iter().all(|s| s.launches > 0));
}

/// Satellite: pool-aware hold planning. With every node leased, a
/// blocked whole-node batch job used to get *no* hold at all (planning
/// found no admissible node and gave up); now the hold's start estimate
/// is borrowed from the fleet's drain forecast, and the job dispatches
/// promptly once the shard shrinks.
#[test]
fn fully_fenced_cluster_still_plans_holds_from_the_drain_forecast() {
    let cfg = PoolConfig {
        size: 2,
        min: 0,
        max: 2,
        ..PoolConfig::disabled()
    };
    let mut sim = quiet_sim(2, 5).with_pool(cfg);
    let mut q = EventQueue::new();
    // Both nodes leased at bootstrap; two 25 s pool jobs occupy them.
    sim.submit_at(&mut q, 0.0, job("pool", 2, ResourceRequest::WholeNode, 25.0, 64));
    // A long whole-node batch job blocks behind the fully-fenced
    // cluster at t = 1.
    sim.submit_at(&mut q, 1.0, job("held", 1, ResourceRequest::WholeNode, 100.0, 64));
    let out = sim.run(&mut q);
    assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    assert!(
        out.max_active_holds >= 1,
        "the blocked job must hold a reservation even though every \
         candidate node is pool-fenced (PR 4 skipped it)"
    );
    assert!(!out.hold_invariant_violated);
    let pool = out.pool.as_ref().unwrap();
    assert!(!pool.invariant_violated);
    assert!(pool.shrinks > 0, "the idle shard gave its nodes back");
    // The held job starts once the pool jobs drain (~25 s) and the
    // shard returns a node — not at 0, and without waiting for any
    // longer fallback.
    let held = out
        .records
        .iter()
        .find(|r| r.cores == 64 && r.end_t.unwrap() - r.start_t.unwrap() > 90.0)
        .expect("held job ran");
    let start = held.start_t.unwrap();
    assert!(
        (24.0..40.0).contains(&start),
        "held job started at {start}, expected shortly after the pool drained"
    );
}

/// Satellite: drain-candidate selection by expected free time. Two busy
/// batch nodes (one freeing at ~41 s, one at ~101 s); the grow path
/// must earmark the one that frees soonest, so the backlogged shard
/// starts serving decades earlier than the old lowest-id rule would.
#[test]
fn grow_drains_the_node_expected_to_free_soonest() {
    let cfg = PoolConfig {
        size: 1,
        min: 1,
        max: 2,
        ..PoolConfig::disabled()
    };
    let mut sim = quiet_sim(3, 9).with_pool(cfg);
    let mut q = EventQueue::new();
    // Node 0 is leased at bootstrap. Two batch jobs occupy the rest:
    // the 100 s job lands on node 1 (first fit), the 40 s job on node 2.
    sim.submit_at(&mut q, 0.0, job("slow", 1, ResourceRequest::WholeNode, 100.0, 64));
    sim.submit_at(&mut q, 0.2, job("fast", 1, ResourceRequest::WholeNode, 40.0, 64));
    // A volley of 20 s pool jobs forces a grow with no idle batch node:
    // the drain candidate decides when the second node joins.
    sim.submit_at(&mut q, 1.0, job("volley", 6, ResourceRequest::WholeNode, 20.0, 64));
    let out = sim.run(&mut q);
    assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    let pool = out.pool.as_ref().unwrap();
    assert!(!pool.invariant_violated);
    assert!(pool.grows >= 2, "bootstrap lease + drain both count");
    // With the expected-free-time rule the 40 s node (node 2) is
    // drained and joins at ~41 s; six 20 s jobs then finish by ~81 s.
    // The old lowest-id rule drained the 100 s node and finished after
    // ~101 s.
    let volley_last_end = out
        .records
        .iter()
        .filter(|r| {
            let d = r.end_t.unwrap() - r.start_t.unwrap();
            (19.0..21.0).contains(&d)
        })
        .map(|r| r.end_t.unwrap())
        .fold(f64::NEG_INFINITY, f64::max);
    assert!(
        volley_last_end < 95.0,
        "volley drained at {volley_last_end}; draining the slow node would have \
         pushed it past 100 s"
    );
    // Both batch jobs ran undisturbed to completion.
    for (name_dur, lo) in [(100.0, 100.0), (40.0, 40.0)] {
        assert!(
            out.records.iter().any(|r| {
                let d = r.end_t.unwrap() - r.start_t.unwrap();
                (d - name_dur).abs() < 1.0 && r.end_t.unwrap() >= lo
            }),
            "batch job of {name_dur}s completed normally"
        );
    }
}

/// The borrow path end-to-end: a shard whose volley outgrows its leases
/// borrows the sibling's idle nodes (sibling queue empty, above its
/// floor) instead of draining busy batch nodes.
#[test]
fn growing_shard_borrows_idle_sibling_nodes() {
    let nodes = 8u32;
    let fleet = FleetConfig {
        shards: vec![
            // The donor: 4 warm leases, floor 1, nothing to do.
            ShardConfig {
                name: "general".into(),
                shape: JobShape::named("general").unwrap(),
                pool: PoolConfig { size: 4, min: 1, max: 6, ..PoolConfig::disabled() },
            },
            // The receiver: 1 warm lease, a 6-task volley incoming.
            ShardConfig {
                name: "large".into(),
                shape: JobShape::named("large").unwrap(),
                pool: PoolConfig { size: 1, min: 1, max: 6, ..PoolConfig::disabled() },
            },
        ],
    };
    let mut sim = quiet_sim(nodes, 3).with_fleet(fleet);
    let mut q = EventQueue::new();
    // Batch work occupies the three unleased nodes, so the only grow
    // sources are the sibling's idle leases (and useless long drains).
    sim.submit_at(&mut q, 0.0, job("batch", 3, ResourceRequest::WholeNode, 300.0, 64));
    sim.submit_at(&mut q, 1.0, job("largevolley", 6, ResourceRequest::WholeNode, 10.0, 64));
    let out = sim.run(&mut q);
    assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    let pool = out.pool.as_ref().unwrap();
    assert!(!pool.invariant_violated);
    assert!(
        pool.borrows >= 1,
        "the large shard must borrow sibling-free nodes (got {} borrows)",
        pool.borrows
    );
    assert_eq!(pool.shards[1].launches, 6, "volley served by the large shard");
    // The volley never waits for the 300 s batch nodes: with borrowed
    // capacity it drains well before any drain could deliver.
    let volley_last_end = out
        .records
        .iter()
        .filter(|r| {
            let d = r.end_t.unwrap() - r.start_t.unwrap();
            (9.0..11.0).contains(&d)
        })
        .map(|r| r.end_t.unwrap())
        .fold(f64::NEG_INFINITY, f64::max);
    assert!(
        volley_last_end < 60.0,
        "volley drained at {volley_last_end}: borrowing should beat any 300 s drain"
    );
}
