//! Integration tests over the PJRT runtime: load the AOT artifacts built
//! by `make artifacts`, execute them, and verify the numerics against the
//! Python-side oracle (`artifacts/expected_checksums.json`) — the
//! cross-language correctness proof for the three-layer stack.

use llsched::exec::payload::Payload;
use llsched::exec::worker::NodeExecutor;
use llsched::aggregation::script::build_scripts;
use llsched::runtime::server::{initial_state, RuntimeServer};
use llsched::runtime::{ExecPool, Runtime};
use std::path::PathBuf;
use std::sync::Arc;

/// The live-execution tests need both a PJRT-capable build (not the
/// offline stub) and the artifacts from `make artifacts`. When either is
/// missing the tests skip (pass vacuously) with a note, so the default
/// offline `cargo test` stays green.
fn runtime_ready() -> Option<PathBuf> {
    if !llsched::runtime::pjrt_available() {
        eprintln!("skipping: PJRT stub build (see runtime::stub)");
        return None;
    }
    let dir = llsched::runtime::find_artifacts_dir();
    if dir.is_none() {
        eprintln!("skipping: artifacts/ not found — run `make artifacts` first");
    }
    dir
}

fn artifacts_dir() -> PathBuf {
    llsched::runtime::find_artifacts_dir().expect("run `make artifacts` first")
}

/// Minimal JSON reader for the oracle file (array of flat objects).
fn oracle_cases() -> Vec<(String, u64, usize, f64)> {
    let text = std::fs::read_to_string(artifacts_dir().join("expected_checksums.json"))
        .expect("expected_checksums.json present");
    let mut out = Vec::new();
    for obj in text.split('{').skip(1) {
        let field = |key: &str| -> String {
            let pat = format!("\"{key}\":");
            let rest = &obj[obj.find(&pat).unwrap() + pat.len()..];
            rest.trim_start()
                .trim_start_matches('"')
                .split(|c| c == '"' || c == ',' || c == '}' || c == '\n')
                .next()
                .unwrap()
                .trim()
                .to_string()
        };
        out.push((
            field("artifact"),
            field("task_id").parse().unwrap(),
            field("invocations").parse().unwrap(),
            field("checksum").parse().unwrap(),
        ));
    }
    out
}

#[test]
fn artifacts_load_and_execute() {
    let Some(_dir) = runtime_ready() else {
        return;
    };
    let mut pool = ExecPool::open(artifacts_dir());
    let files = pool.list().unwrap();
    assert_eq!(files.len(), 3, "three shape variants exported");
    for f in &files {
        let name = f
            .file_name()
            .and_then(|n| n.to_str())
            .and_then(|n| n.strip_suffix(".hlo.txt"))
            .unwrap()
            .to_string();
        let rt = pool.get(&name).unwrap();
        let state = vec![0.25f32; rt.artifact.elements()];
        let (out, checksum) = rt.step(&state).unwrap();
        assert_eq!(out.len(), state.len());
        assert!(out.iter().all(|x| x.is_finite()));
        assert!(checksum.is_finite());
    }
}

#[test]
fn step_is_deterministic() {
    let Some(_dir) = runtime_ready() else {
        return;
    };
    let rt = Runtime::load(&artifacts_dir().join("simstep_8x32x32.hlo.txt")).unwrap();
    let state = initial_state(&rt.artifact, 5);
    let (a, ca) = rt.step(&state).unwrap();
    let (b, cb) = rt.step(&state).unwrap();
    assert_eq!(a, b);
    assert_eq!(ca, cb);
}

#[test]
fn uniform_field_matches_closed_form() {
    let Some(_dir) = runtime_ready() else {
        return;
    };
    // A constant field has zero laplacian: each inner step applies only
    // the cubic damping y - 0.01*y^3; the module runs 4 scan steps.
    let rt = Runtime::load(&artifacts_dir().join("simstep_8x32x32.hlo.txt")).unwrap();
    let state = vec![0.5f32; rt.artifact.elements()];
    let (out, _) = rt.step(&state).unwrap();
    let mut v = 0.5f64;
    for _ in 0..4 {
        v = v - 0.01 * v * v * v;
    }
    for &x in out.iter().take(16) {
        assert!((x as f64 - v).abs() < 1e-5, "{x} vs {v}");
    }
}

#[test]
fn checksums_match_python_oracle() {
    let Some(_dir) = runtime_ready() else {
        return;
    };
    let cases = oracle_cases();
    assert!(cases.len() >= 4, "oracle has cases");
    let mut pool = ExecPool::open(artifacts_dir());
    for (artifact, task_id, invocations, expected) in cases {
        let rt = pool.get(&artifact).unwrap();
        let state = initial_state(&rt.artifact, task_id);
        let (_, checksum) = rt.run_task(&state, invocations).unwrap();
        let rel = ((checksum as f64 - expected) / expected.abs().max(1e-9)).abs();
        assert!(
            rel < 1e-4,
            "{artifact} task {task_id} x{invocations}: rust {checksum} vs python {expected}"
        );
    }
}

#[test]
fn runtime_server_serves_lanes() {
    let Some(_dir) = runtime_ready() else {
        return;
    };
    let server = Arc::new(
        RuntimeServer::spawn(artifacts_dir().join("simstep_8x32x32.hlo.txt")).unwrap(),
    );
    // Same task twice → identical checksum; different tasks differ.
    let a = server.run_task(1, 1).unwrap();
    let b = server.run_task(1, 1).unwrap();
    let c = server.run_task(2, 1).unwrap();
    assert_eq!(a, b);
    assert_ne!(a, c);
}

#[test]
fn node_executor_runs_pjrt_payload_under_generated_script() {
    let Some(_dir) = runtime_ready() else {
        return;
    };
    // The full L3→L1 path: node-based script → pinned lanes → PJRT tasks.
    let server = Arc::new(
        RuntimeServer::spawn(artifacts_dir().join("simstep_8x32x32.hlo.txt")).unwrap(),
    );
    let scripts = build_scripts(8, 1, 4, 1);
    let rep = NodeExecutor::default()
        .run(
            &scripts[0],
            &Payload::Simulate { server: server.clone(), iters: 1 },
        )
        .unwrap();
    assert_eq!(rep.tasks_run, 8);
    assert_eq!(rep.tasks_failed, 0);
    assert_ne!(rep.checksum_fold, 0, "checksums folded in");
}
