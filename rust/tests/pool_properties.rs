//! Properties of the elastic rapid-launch node pool, from the
//! membership bookkeeping up through the scheduler end-to-end.
//!
//! Four families of invariants pin the subsystem down:
//!
//! 1. **Conservation** — under fuzzed grow/shrink/drain churn, every
//!    node is exactly one of batch/leased/draining, the counters agree
//!    with the membership table, and the free list holds exactly the
//!    idle leases; checked at the pool level (random op sequences) and
//!    end-to-end through burst runs.
//! 2. **Fencing** — no leased or draining node ever appears in a
//!    `FreeIndex` fit result once the pool fence predicate is applied,
//!    under randomized lease sets and allocation churn; end-to-end, no
//!    batch placement ever lands on a pool-owned node.
//! 3. **Pool-off equivalence** — with the pool disabled the scheduler
//!    reproduces the PR 3 schedules bit-for-bit (same records, same
//!    event counts), across ≥ 8 generated seeds and through the classic
//!    contention entry point.
//! 4. **Rapid launch** — on the burst scenario (periodic 1000-task
//!    short-job volleys over a sustained batch stream), the pooled
//!    median launch latency is strictly lower than backfill-only, and
//!    the elastic resize actually exercises both directions.
//!
//! Plus the preemptive-backfill satellite: with `preempt_overdue` on,
//! overdue backfilled tasks are killed when their node's hold comes
//! due, and the held job never starts later than it would have waiting
//! for them to vacate.

use llsched::cluster::{Cluster, NodeId};
use llsched::coordinator::experiment::{run_contention, run_contention_with, ContentionOpts};
use llsched::placement::FreeIndex;
use llsched::pool::{NodeDispatcher, NodePool, PoolConfig, PoolManager, Resize};
use llsched::scheduler::core::{SchedulerSim, SimOutcome, TaskModel};
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::job::{ComputeBatch, JobSpec, ResourceRequest, SchedTaskSpec, TaskState};
use llsched::scheduler::noise::NoiseModel;
use llsched::sim::EventQueue;
use llsched::testing::prop::forall;
use llsched::workload::contention::{ContentionMix, WalltimeError};

fn quiet_sim(nodes: u32, seed: u64) -> SchedulerSim {
    SchedulerSim::new(
        Cluster::tx_green(nodes),
        CostModel::slurm_like_tx_green(),
        NoiseModel::dedicated(),
        seed,
    )
    .with_task_model(TaskModel {
        startup: 0.0,
        jitter_sigma: 0.0,
        p_node_late: 0.0,
        late_range: (0.0, 0.0),
    })
    .with_server_speed(1.0)
    .with_backfill(true)
}

fn job(
    name: &str,
    n_tasks: usize,
    request: ResourceRequest,
    duration: f64,
    priority: i32,
) -> JobSpec {
    let lanes = match request {
        ResourceRequest::WholeNode => 64,
        ResourceRequest::Cores { cores, .. } => cores,
    };
    JobSpec {
        name: name.into(),
        tasks: vec![
            SchedTaskSpec {
                request,
                duration,
                batch: ComputeBatch { count: 1, each: duration },
                lanes,
            };
            n_tasks
        ],
        reservation: None,
        priority,
        preemptable: false,
    }
}

/// Property 1, pool level: random valid op sequences (driven through a
/// manager making real decisions) never break conservation.
#[test]
fn conservation_under_fuzzed_pool_churn() {
    forall("pool conservation under churn", 40, |g| {
        let n = 2 + g.usize(0, 30);
        let mut pool = NodePool::new(n);
        let mut disp = NodeDispatcher::new();
        let max = 1 + g.usize(0, n - 1);
        let min = g.usize(0, max);
        let mgr = PoolManager::new(min, max, g.f64(0.0, 0.9));
        let mut queued = g.usize(0, 40);
        let mut busy: Vec<NodeId> = Vec::new();
        for step in 0..200 {
            match g.usize(0, 5) {
                // Demand / completion churn.
                0 => queued = queued.saturating_add(g.usize(0, 10)),
                1 => {
                    if let Some(node) = disp.launch(&mut pool) {
                        queued = queued.saturating_sub(1);
                        busy.push(node);
                    }
                }
                2 => {
                    if !busy.is_empty() {
                        let node = busy.remove(g.usize(0, busy.len() - 1));
                        if !disp.release(&mut pool, node) {
                            return Err(format!("release of busy lease {node} refused"));
                        }
                    }
                }
                // Drain completion: a draining node goes idle.
                3 => {
                    if let Some(node) = pool.any_draining() {
                        pool.promote(node);
                    }
                }
                // Manager-driven resize, applied the way the scheduler
                // applies it (lease idle batch nodes, else drain; shrink
                // from the free list, else cancel drains).
                _ => match mgr.decide(
                    queued,
                    pool.n_free(),
                    pool.n_leased(),
                    pool.n_draining(),
                ) {
                    Resize::Grow(k) => {
                        for _ in 0..k {
                            let cand = (0..n as NodeId).find(|&id| !pool.in_pool(id));
                            match cand {
                                Some(id) => {
                                    // Half the grows lease (idle batch
                                    // node), half drain (busy one).
                                    if g.chance(0.5) {
                                        pool.lease(id);
                                    } else {
                                        pool.begin_drain(id);
                                    }
                                }
                                None => break,
                            }
                        }
                    }
                    Resize::Shrink(k) => {
                        for _ in 0..k {
                            if pool.return_free().is_none() {
                                if let Some(d) = pool.any_draining() {
                                    pool.cancel_drain(d);
                                } else {
                                    break;
                                }
                            }
                        }
                    }
                    Resize::Hold => {}
                },
            }
            pool.check_conservation()
                .map_err(|e| format!("step {step}: {e}"))?;
            if pool.n_leased() + pool.n_draining() + pool.n_batch() != n {
                return Err(format!("step {step}: membership does not partition the cluster"));
            }
            if pool.n_free() + busy.len() != pool.n_leased() {
                return Err(format!(
                    "step {step}: free {} + busy {} != leased {}",
                    pool.n_free(),
                    busy.len(),
                    pool.n_leased()
                ));
            }
        }
        Ok(())
    });
}

/// Property 2, index level: the pool fence predicate keeps every
/// leased/draining node out of every `FreeIndex` query the batch
/// scheduler runs, under randomized lease sets and allocation churn.
#[test]
fn leased_nodes_never_appear_in_fit_results() {
    forall("pool fence over the index", 30, |g| {
        let n = 2 + g.usize(0, 14);
        let mut cluster = Cluster::tx_green(n as u32);
        let mut index = FreeIndex::build(&cluster);
        let mut pool = NodePool::new(n);
        for id in 0..n as NodeId {
            if g.chance(0.4) {
                if g.chance(0.7) {
                    pool.lease(id);
                } else {
                    pool.begin_drain(id);
                }
            }
        }
        // Random partial allocations on batch nodes (leased nodes stay
        // untouched by the cluster — the pool bypasses it — so they
        // look idle to the index, which is exactly what makes the
        // fence load-bearing).
        for id in 0..n as NodeId {
            if !pool.in_pool(id) && g.chance(0.5) {
                let cores = 1 + g.usize(0, 63) as u32;
                cluster.allocate_on(id, cores, 0).unwrap();
                index.on_delta(id, cluster.node(id).unwrap().free_cores());
            }
        }
        let fence = |id: NodeId| !pool.in_pool(id);
        for cores in [1u32, 8, 64] {
            for _ in 0..4 {
                if let Some(hit) = index.first_fit_where(&cluster, 0, cores, 0, fence) {
                    if pool.in_pool(hit) {
                        return Err(format!("first_fit_where returned pooled node {hit}"));
                    }
                }
                if let Some(hit) = index.best_fit_where(&cluster, 0, cores, 0, fence) {
                    if pool.in_pool(hit) {
                        return Err(format!("best_fit_where returned pooled node {hit}"));
                    }
                }
            }
        }
        if let Some(hit) = index.idle_lowest_where(&cluster, 0, fence) {
            if pool.in_pool(hit) {
                return Err(format!("idle_lowest_where returned pooled node {hit}"));
            }
        }
        Ok(())
    });
}

/// Property 3: with the pool disabled, schedules are bit-for-bit the
/// PR 3 ones — directly through the scheduler across ≥ 8 generated
/// seeds (whole-node + core-level mixes, backfill on).
#[test]
fn pool_off_reproduces_pr3_schedules_bit_for_bit() {
    forall("pool-off equivalence", 10, |g| {
        let nodes = 2 + g.usize(0, 3) as u32;
        let seed = g.int(0, u64::MAX - 1);
        let mut subs: Vec<(f64, JobSpec)> = vec![(
            0.3 + 2.5 * g.usize(0, 4) as f64,
            job(
                "batch",
                1 + g.usize(0, nodes as usize),
                ResourceRequest::WholeNode,
                g.f64(20.0, 60.0),
                0,
            ),
        )];
        let n_small = 5 + g.usize(0, 15);
        for i in 0..n_small {
            let cores = 1u32 << g.int(0, 5);
            subs.push((
                1.0 + 1.25 * i as f64,
                job(
                    &format!("small-{i}"),
                    1 + g.usize(0, 2),
                    ResourceRequest::Cores { cores, mem_mib: 0 },
                    g.f64(1.0, 12.0),
                    g.int(0, 10) as i32,
                ),
            ));
        }
        let run = |mut sim: SchedulerSim| -> SimOutcome {
            let mut q = EventQueue::new();
            for (at, spec) in &subs {
                sim.submit_at(&mut q, *at, spec.clone());
            }
            sim.run(&mut q)
        };
        let legacy = run(quiet_sim(nodes, seed));
        let gated = run(
            quiet_sim(nodes, seed)
                .with_pool(PoolConfig::disabled())
                .with_preempt_overdue(false),
        );
        if gated.pool.is_some() {
            return Err("disabled pool produced an outcome".into());
        }
        if legacy.records.len() != gated.records.len() {
            return Err("record count diverged".into());
        }
        for (a, b) in legacy.records.iter().zip(&gated.records) {
            if a.state != b.state
                || a.start_t != b.start_t
                || a.end_t != b.end_t
                || a.cleanup_t != b.cleanup_t
                || a.cores != b.cores
            {
                return Err(format!("task {} diverged: {a:?} vs {b:?}", a.task));
            }
        }
        if legacy.backfills.len() != gated.backfills.len() {
            return Err("backfill count diverged".into());
        }
        if legacy.events_processed != gated.events_processed {
            return Err("event count diverged".into());
        }
        Ok(())
    });
}

/// Property 3, contention level: the classic wrapper and an explicit
/// pool-disabled run agree exactly on burst and tiny mixes (8 seeds).
#[test]
fn pool_off_contention_matches_classic_wrapper() {
    for seed in 0..8u64 {
        for preset in ["tiny", "burst"] {
            let mix = ContentionMix::preset(preset, 16).unwrap();
            let classic = run_contention(&mix, true, seed).unwrap();
            let gated = run_contention_with(
                &mix,
                ContentionOpts {
                    pool: PoolConfig::disabled(),
                    ..ContentionOpts::classic(true, seed)
                },
            )
            .unwrap();
            assert!(gated.pool.is_none());
            assert_eq!(classic.span, gated.span, "{preset}/{seed}: span diverged");
            assert_eq!(classic.backfills, gated.backfills);
            assert_eq!(classic.unfinished, gated.unfinished);
            for (a, b) in classic.reports.iter().zip(&gated.reports) {
                assert_eq!(
                    a.median_launch_latency, b.median_launch_latency,
                    "{preset}/{seed}: median diverged"
                );
                assert_eq!(a.core_seconds, b.core_seconds);
            }
        }
    }
}

/// Property 4 + the acceptance regression: on the burst scenario the
/// pooled median launch latency for the short-job volleys is strictly
/// lower than backfill-only, the run stays conservation-clean, and the
/// elastic resize exercises both grow and shrink.
#[test]
fn pooled_burst_beats_backfill_only_latency() {
    let nodes = 128u32;
    let mix = ContentionMix::preset("burst", nodes).unwrap();
    let seed = 11;
    let baseline = run_contention(&mix, true, seed).unwrap();
    let n = nodes as usize;
    let pooled = run_contention_with(
        &mix,
        ContentionOpts {
            pool: PoolConfig {
                size: n / 4,
                min: n / 8,
                max: 3 * n / 4,
                ..PoolConfig::disabled()
            },
            ..ContentionOpts::classic(true, seed)
        },
    )
    .unwrap();
    assert_eq!(baseline.unfinished, 0, "baseline drains");
    assert_eq!(pooled.unfinished, 0, "pooled run drains");
    let pool = pooled.pool.as_ref().expect("pool report");
    let inter_base = &baseline.reports[0];
    let inter_pool = &pooled.reports[0];
    assert_eq!(
        pool.launches, inter_pool.tasks as u64,
        "every short whole-node task went through the pool"
    );
    assert!(
        inter_pool.median_launch_latency < inter_base.median_launch_latency,
        "pooled median {} must beat backfill-only {}",
        inter_pool.median_launch_latency,
        inter_base.median_launch_latency
    );
    // Elasticity actually happened: the pool grew under volley pressure
    // and gave nodes back between volleys.
    assert!(pool.grows > 0, "pool never grew");
    assert!(pool.shrinks > 0, "pool never shrank");
    assert!(pool.peak_leased > n / 4, "peak {} never exceeded the seed size", pool.peak_leased);
    assert!(pool.peak_leased <= 3 * n / 4);
    // Batch kept running underneath.
    let batch = &pooled.reports[1];
    assert_eq!(batch.completed, batch.tasks, "batch stream drained too");
}

/// End-to-end conservation + fencing: a pooled burst run never breaks
/// the pool invariants (checked inside the scheduler after every
/// resize and release, surfaced through the outcome flag).
#[test]
fn burst_run_keeps_pool_invariants() {
    for seed in [1u64, 7, 23] {
        let mut sim = quiet_sim(32, seed).with_pool(PoolConfig {
            size: 8,
            min: 2,
            max: 24,
            ..PoolConfig::disabled()
        });
        let mut q = EventQueue::new();
        let mix = ContentionMix::preset("burst", 32).unwrap();
        for sub in mix.generate(seed) {
            sim.submit_at(&mut q, sub.at, sub.spec);
        }
        let out = sim.run(&mut q);
        assert!(out.records.iter().all(|r| r.state == TaskState::Done), "seed {seed}");
        let pool = out.pool.expect("pool outcome");
        assert!(!pool.invariant_violated, "seed {seed}: pool invariants broken");
        assert!(pool.launches > 0);
        assert!(!out.hold_invariant_violated);
    }
}

/// Preemptive backfill satellite: overdue backfilled tasks on a due
/// hold's node are killed through the preempt path, and the held job
/// starts no later than it would have waiting for them — strictly
/// earlier whenever a kill actually fired.
#[test]
fn preempt_overdue_frees_due_holds() {
    let mut any_preempted = 0u64;
    for seed in 0..8u64 {
        let build = |preempt: bool| -> (SimOutcome, u64) {
            let mut sim = quiet_sim(2, seed)
                .with_walltime_error(WalltimeError::Uniform { frac: 0.9 })
                .with_preempt_overdue(preempt);
            let mut q = EventQueue::new();
            // Two 56-core anchors occupy both nodes (leaving 8-core
            // gaps), a whole-node job blocks behind them and plans a
            // hold, and a stream of 60 s core-level tasks offers
            // backfill bait whose noisy estimates (uniform ±90%) are
            // routinely wild underestimates — those get admitted, then
            // overstay the hold.
            sim.submit_at(
                &mut q,
                0.0,
                job("anchor", 2, ResourceRequest::Cores { cores: 56, mem_mib: 0 }, 20.0, 0),
            );
            let held = sim.submit_at(
                &mut q,
                1.0,
                job("held", 1, ResourceRequest::WholeNode, 10.0, 5),
            );
            for i in 0..30u64 {
                sim.submit_at(
                    &mut q,
                    2.0 + 0.4 * i as f64,
                    job(
                        &format!("bait-{i}"),
                        1,
                        ResourceRequest::Cores { cores: 8, mem_mib: 0 },
                        60.0,
                        -2,
                    ),
                );
            }
            (sim.run(&mut q), held)
        };
        let (on, held_on) = build(true);
        let (off, held_off) = build(false);
        assert!(on.records.iter().all(|r| r.state == TaskState::Done), "seed {seed}");
        assert!(off.records.iter().all(|r| r.state == TaskState::Done), "seed {seed}");
        assert_eq!(off.overdue_preemptions, 0, "off path never kills");
        let start = |out: &SimOutcome, job_id: u64| -> f64 {
            out.records
                .iter()
                .filter(|r| r.job == job_id)
                .map(|r| r.start_t.expect("started"))
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let s_on = start(&on, held_on);
        let s_off = start(&off, held_off);
        // Never meaningfully later (small slack: post-divergence server
        // op ordering can shift dispatch instants by a few op costs).
        assert!(
            s_on <= s_off + 5.0,
            "seed {seed}: preemption delayed the held job ({s_on} > {s_off})"
        );
        if on.overdue_preemptions > 0 {
            any_preempted += on.overdue_preemptions;
            // The whole point: a kill frees the held node long before
            // the overdue bait's natural 60 s occupancy would have.
            assert!(
                s_on + 1.0 < s_off,
                "seed {seed}: kills fired but the held job gained nothing \
                 ({s_on} vs {s_off})"
            );
            // A killed task demonstrably ended before its natural
            // occupancy would have.
            let killed_early = on.records.iter().any(|r| {
                matches!(r.start_t, Some(s) if matches!(r.end_t, Some(e) if e - s < 59.0))
                    && r.cores == 8
            });
            assert!(killed_early, "seed {seed}: no record shows an early kill");
        }
    }
    assert!(
        any_preempted > 0,
        "no seed ever triggered an overdue preemption — the scenario lost its bait"
    );
}
