//! Schedule-equivalence suite for the event-calendar hot path.
//!
//! The wake-driven dispatch loop (per-shard wake events + dirty sets),
//! the ranged arena `Register`, and the calendar event-queue backend
//! are all pure performance mechanisms: none of them may change a
//! single scheduling decision. This suite pins that down:
//!
//! 1. **Polled vs wake-driven, preset level** — on three contention
//!    presets (`burst`, `burst_mixed`, `heavy`) with the rapid-launch
//!    fleet enabled, both hot paths agree on span, per-class latency
//!    quantiles, backfill counts, and the full pool ledger.
//! 2. **Polled vs wake-driven, fuzzed** — 12 generated workloads
//!    (random node counts, job mixes, pool shapes, hold depths, aging
//!    on/off, preemptive backfill on/off) produce bit-for-bit identical
//!    task records, event counts, busy breakdowns, and pool outcomes.
//! 3. **Binary-heap vs calendar queue** — the same workload driven
//!    through either [`QueueBackend`] yields identical schedules.
//! 4. **Ranged vs legacy `Register`** — the arena task-range walk and
//!    the historical full-arena filter scan enqueue the same tasks in
//!    the same order, so outcomes match exactly.

use llsched::cluster::Cluster;
use llsched::coordinator::experiment::{run_contention_with, ContentionOpts};
use llsched::pool::{PoolConfig, ShardConfig};
use llsched::scheduler::core::{SchedulerSim, SimOutcome, TaskModel};
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::job::{ComputeBatch, JobSpec, ResourceRequest, SchedTaskSpec};
use llsched::scheduler::noise::NoiseModel;
use llsched::scheduler::queue::AgingPolicy;
use llsched::scheduler::HotPath;
use llsched::sim::{EventQueue, QueueBackend};
use llsched::testing::prop::forall;
use llsched::workload::contention::ContentionMix;

fn quiet_sim(nodes: u32, seed: u64) -> SchedulerSim {
    SchedulerSim::new(
        Cluster::tx_green(nodes),
        CostModel::slurm_like_tx_green(),
        NoiseModel::dedicated(),
        seed,
    )
    .with_task_model(TaskModel {
        startup: 0.0,
        jitter_sigma: 0.0,
        p_node_late: 0.0,
        late_range: (0.0, 0.0),
    })
    .with_server_speed(1.0)
    .with_backfill(true)
}

fn job(
    name: &str,
    n_tasks: usize,
    request: ResourceRequest,
    duration: f64,
    priority: i32,
) -> JobSpec {
    let lanes = match request {
        ResourceRequest::WholeNode => 64,
        ResourceRequest::Cores { cores, .. } => cores,
    };
    JobSpec {
        name: name.into(),
        tasks: vec![
            SchedTaskSpec {
                request,
                duration,
                batch: ComputeBatch { count: 1, each: duration },
                lanes,
            };
            n_tasks
        ],
        reservation: None,
        priority,
        preemptable: false,
    }
}

/// A fuzzed workload: one long batch job plus a stream of small jobs,
/// some whole-node (pool-routable), some core-level.
fn fuzzed_subs(g: &mut llsched::testing::prop::Gen, nodes: u32) -> Vec<(f64, JobSpec)> {
    let mut subs: Vec<(f64, JobSpec)> = vec![(
        0.3 + 2.0 * g.usize(0, 4) as f64,
        job(
            "batch",
            1 + g.usize(0, nodes as usize),
            ResourceRequest::WholeNode,
            g.f64(20.0, 60.0),
            0,
        ),
    )];
    let n_small = 6 + g.usize(0, 14);
    for i in 0..n_small {
        let whole = g.usize(0, 2) > 0;
        let request = if whole {
            ResourceRequest::WholeNode
        } else {
            ResourceRequest::Cores { cores: 1u32 << g.int(0, 5), mem_mib: 0 }
        };
        subs.push((
            0.8 + 1.1 * i as f64,
            job(
                &format!("small-{i}"),
                1 + g.usize(0, 3),
                request,
                g.f64(0.5, if whole { 6.0 } else { 12.0 }),
                g.int(0, 10) as i32,
            ),
        ));
    }
    subs
}

fn run_with(
    mut sim: SchedulerSim,
    subs: &[(f64, JobSpec)],
    backend: QueueBackend,
) -> SimOutcome {
    let mut q = EventQueue::with_backend(backend);
    for (at, spec) in subs {
        sim.submit_at(&mut q, *at, spec.clone());
    }
    sim.run(&mut q)
}

/// Assert two outcomes are the same schedule, bit for bit.
fn assert_same_schedule(a: &SimOutcome, b: &SimOutcome, what: &str) -> Result<(), String> {
    if a.records.len() != b.records.len() {
        return Err(format!("{what}: record count diverged"));
    }
    for (x, y) in a.records.iter().zip(&b.records) {
        if x.state != y.state
            || x.start_t != y.start_t
            || x.end_t != y.end_t
            || x.cleanup_t != y.cleanup_t
            || x.cores != y.cores
            || x.pool_shard != y.pool_shard
        {
            return Err(format!("{what}: task {} diverged: {x:?} vs {y:?}", x.task));
        }
    }
    if a.backfills.len() != b.backfills.len() {
        return Err(format!("{what}: backfill count diverged"));
    }
    if a.events_processed != b.events_processed {
        return Err(format!(
            "{what}: event count diverged ({} vs {})",
            a.events_processed, b.events_processed
        ));
    }
    if a.final_time != b.final_time {
        return Err(format!("{what}: final time diverged"));
    }
    if a.busy.total() != b.busy.total()
        || a.busy.register != b.busy.register
        || a.busy.dispatch != b.busy.dispatch
        || a.busy.cleanup != b.busy.cleanup
        || a.busy.pool != b.busy.pool
    {
        return Err(format!(
            "{what}: busy breakdown diverged: {:?} vs {:?}",
            a.busy, b.busy
        ));
    }
    match (&a.pool, &b.pool) {
        (None, None) => {}
        (Some(p), Some(q)) => {
            if p.launches != q.launches
                || p.recent_launches != q.recent_launches
                || p.grows != q.grows
                || p.shrinks != q.shrinks
                || p.peak_leased != q.peak_leased
                || p.final_leased != q.final_leased
                || p.borrows != q.borrows
            {
                return Err(format!("{what}: pool ledger diverged"));
            }
        }
        _ => return Err(format!("{what}: pool presence diverged")),
    }
    if a.overdue_preemptions != b.overdue_preemptions {
        return Err(format!("{what}: preemption count diverged"));
    }
    Ok(())
}

/// Equivalence 1: three presets through the contention entry point,
/// fleet on, both hot paths — identical results end to end.
#[test]
fn wake_driven_matches_polled_on_presets() {
    for (preset, nodes, seed) in [("burst", 64u32, 11u64), ("burst_mixed", 16, 7), ("heavy", 32, 3)]
    {
        let mix = ContentionMix::preset(preset, nodes).unwrap();
        let opts_for = |hp: HotPath| {
            let mut o = if preset == "burst_mixed" {
                ContentionOpts {
                    pools: vec![
                        ShardConfig::named("general", 4, 2, 10).unwrap(),
                        ShardConfig::named("large", 2, 1, 6).unwrap(),
                    ],
                    ..ContentionOpts::classic(true, seed)
                }
            } else {
                ContentionOpts {
                    pool: PoolConfig { size: 4, min: 2, max: 8, ..PoolConfig::sized(4) },
                    holds: 2,
                    ..ContentionOpts::classic(true, seed)
                }
            };
            o.hot_path = hp;
            o
        };
        let polled = run_contention_with(&mix, opts_for(HotPath::Polled)).unwrap();
        let woken = run_contention_with(&mix, opts_for(HotPath::WakeDriven)).unwrap();
        assert_eq!(polled.span, woken.span, "{preset}: span diverged");
        assert_eq!(polled.backfills, woken.backfills, "{preset}: backfills diverged");
        assert_eq!(polled.unfinished, woken.unfinished, "{preset}: unfinished diverged");
        assert_eq!(
            polled.max_active_holds, woken.max_active_holds,
            "{preset}: hold peak diverged"
        );
        assert_eq!(
            polled.overdue_preemptions, woken.overdue_preemptions,
            "{preset}: preemptions diverged"
        );
        for (a, b) in polled.reports.iter().zip(&woken.reports) {
            assert_eq!(
                a.median_launch_latency, b.median_launch_latency,
                "{preset}: median latency diverged"
            );
            assert_eq!(
                a.p95_launch_latency, b.p95_launch_latency,
                "{preset}: p95 latency diverged"
            );
            assert_eq!(a.core_seconds, b.core_seconds, "{preset}: core-seconds diverged");
            assert_eq!(a.completed, b.completed, "{preset}: completions diverged");
        }
        let (pp, wp) = (polled.pool.as_ref().unwrap(), woken.pool.as_ref().unwrap());
        assert_eq!(pp.launches, wp.launches, "{preset}: pool launches diverged");
        assert_eq!(pp.grows, wp.grows, "{preset}: pool grows diverged");
        assert_eq!(pp.shrinks, wp.shrinks, "{preset}: pool shrinks diverged");
        assert_eq!(pp.peak_leased, wp.peak_leased, "{preset}: pool peak diverged");
        assert_eq!(pp.borrows, wp.borrows, "{preset}: pool borrows diverged");
        assert_eq!(
            pp.median_launch_latency, wp.median_launch_latency,
            "{preset}: pool latency diverged"
        );
    }
}

/// Equivalence 2: 12 fuzzed workloads, polled vs wake-driven — the
/// schedules are bit-for-bit identical, including the event count (the
/// wake events are scheduled in both modes so the streams match).
#[test]
fn wake_driven_matches_polled_fuzzed() {
    forall("wake-driven equivalence", 12, |g| {
        let nodes = 2 + g.usize(0, 6) as u32;
        let seed = g.int(0, u64::MAX - 1);
        let subs = fuzzed_subs(g, nodes);
        let max = 1 + g.usize(0, (nodes as usize).saturating_sub(1).max(1));
        let min = g.usize(0, max.min(2));
        let pool = PoolConfig { size: max.min(2), min, max, ..PoolConfig::sized(max) };
        let holds = 1 + g.usize(0, 2);
        let aging = if g.usize(0, 2) == 0 {
            Some(AgingPolicy::new(0.5, 100))
        } else {
            None
        };
        let preempt = g.usize(0, 3) == 0;
        let build = |hp: HotPath| {
            quiet_sim(nodes, seed)
                .with_pool(pool)
                .with_holds(holds)
                .with_aging(aging.clone())
                .with_preempt_overdue(preempt)
                .with_hot_path(hp)
        };
        let polled = run_with(build(HotPath::Polled), &subs, QueueBackend::Binary);
        let woken = run_with(build(HotPath::WakeDriven), &subs, QueueBackend::Binary);
        assert_same_schedule(&polled, &woken, "polled vs wake-driven")
    });
}

/// Equivalence 3: the calendar-queue backend is a drop-in replacement
/// for the binary heap — same schedule, same event count.
#[test]
fn calendar_backend_matches_binary_heap() {
    forall("calendar backend equivalence", 8, |g| {
        let nodes = 2 + g.usize(0, 5) as u32;
        let seed = g.int(0, u64::MAX - 1);
        let subs = fuzzed_subs(g, nodes);
        let pool = PoolConfig { size: 2, min: 1, max: nodes as usize, ..PoolConfig::sized(2) };
        let build = || quiet_sim(nodes, seed).with_pool(pool).with_holds(2);
        let heap = run_with(build(), &subs, QueueBackend::Binary);
        let cal = run_with(build(), &subs, QueueBackend::Calendar);
        assert_same_schedule(&heap, &cal, "binary vs calendar")
    });
}

/// Equivalence 4: the ranged arena `Register` walk enqueues exactly
/// what the legacy full-arena filter scan did.
#[test]
fn ranged_register_matches_legacy_scan() {
    forall("ranged register equivalence", 8, |g| {
        let nodes = 2 + g.usize(0, 5) as u32;
        let seed = g.int(0, u64::MAX - 1);
        let subs = fuzzed_subs(g, nodes);
        let pool = PoolConfig { size: 2, min: 1, max: nodes as usize, ..PoolConfig::sized(2) };
        let build = |legacy: bool| {
            quiet_sim(nodes, seed)
                .with_pool(pool)
                .with_holds(2)
                .with_legacy_register(legacy)
        };
        let old = run_with(build(true), &subs, QueueBackend::Binary);
        let new = run_with(build(false), &subs, QueueBackend::Binary);
        assert_same_schedule(&old, &new, "legacy vs ranged register")
    });
}
