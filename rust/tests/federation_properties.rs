//! Property suite for the federation gateway (`llsched::federation`).
//!
//! 1. **Pass-through equivalence** — a gateway over a single instance
//!    with batch size 1 is a pure pass-through: the schedule it produces
//!    is bit-for-bit the schedule the same sim produces when driven
//!    directly (same task records, event count, final clock). The
//!    lock-step `run_until_before` discipline earns its keep here: an
//!    injected Submit plays exactly as if it had been queued up front.
//! 2. **Conservation under stealing, fuzzed** — random fleets, batch
//!    sizes, steal thresholds and job streams: no job is ever lost or
//!    duplicated across migrations; every job completes exactly once on
//!    its final owner; steal counters balance.
//! 3. **Stealing improves tail latency** — 4 × 128-node partitions with
//!    a skewed mix (three partitions pinned by long whole-machine jobs,
//!    then a burst of short jobs): work stealing must cut the short-job
//!    p95 launch latency at least in half vs the same fleet with
//!    stealing disabled.

use llsched::cluster::Cluster;
use llsched::federation::{FederationConfig, Gateway};
use llsched::placement::Strategy;
use llsched::scheduler::core::{SchedulerSim, SimOutcome};
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::job::{ComputeBatch, JobSpec, ResourceRequest, SchedTaskSpec};
use llsched::scheduler::noise::NoiseModel;
use llsched::sim::EventQueue;
use llsched::testing::prop::{forall, Gen};
use llsched::workload::contention::{ContentionMix, JobClass, Submission};

fn quiet_sim(nodes: u32, seed: u64) -> SchedulerSim {
    SchedulerSim::new(
        Cluster::tx_green(nodes),
        CostModel::slurm_like_tx_green(),
        NoiseModel::dedicated(),
        seed,
    )
    .with_placement(Strategy::NodeBased)
    .with_backfill(true)
}

fn fleet(cfg: FederationConfig, nodes_each: u32, seed: u64) -> Gateway {
    let sims = (0..cfg.instances)
        .map(|i| quiet_sim(nodes_each, seed.wrapping_add(i as u64)))
        .collect();
    Gateway::new(cfg, sims)
}

fn job(name: &str, tasks: usize, request: ResourceRequest, duration: f64) -> JobSpec {
    let lanes = match request {
        ResourceRequest::WholeNode => 64,
        ResourceRequest::Cores { cores, .. } => cores,
    };
    JobSpec {
        name: name.into(),
        tasks: vec![
            SchedTaskSpec {
                request,
                duration,
                batch: ComputeBatch { count: 1, each: duration },
                lanes,
            };
            tasks
        ],
        reservation: None,
        priority: 0,
        preemptable: false,
    }
}

/// Compare two schedules bit for bit (the pass-through contract).
fn assert_same_schedule(a: &SimOutcome, b: &SimOutcome) -> Result<(), String> {
    if a.records.len() != b.records.len() {
        return Err("record count diverged".into());
    }
    for (x, y) in a.records.iter().zip(&b.records) {
        if x.state != y.state
            || x.start_t != y.start_t
            || x.end_t != y.end_t
            || x.cleanup_t != y.cleanup_t
            || x.cores != y.cores
            || x.pool_shard != y.pool_shard
        {
            return Err(format!("task {} diverged: {x:?} vs {y:?}", x.task));
        }
    }
    if a.events_processed != b.events_processed {
        return Err(format!(
            "event count diverged ({} vs {})",
            a.events_processed, b.events_processed
        ));
    }
    if a.final_time != b.final_time {
        return Err(format!(
            "final time diverged ({} vs {})",
            a.final_time, b.final_time
        ));
    }
    Ok(())
}

/// Property 1: N = 1, batch = 1 gateway ≡ driving the sim directly.
#[test]
fn single_instance_gateway_is_a_passthrough() {
    for (preset, nodes, seed) in [("tiny", 8u32, 7u64), ("tiny", 8, 42), ("default", 16, 3)] {
        let mix = ContentionMix::preset(preset, nodes).unwrap();
        let subs = mix.generate(seed);

        let mut sim = quiet_sim(nodes, seed);
        let mut q = EventQueue::new();
        for sub in &subs {
            sim.submit_at(&mut q, sub.at, sub.spec.clone());
        }
        let direct = sim.run(&mut q);

        let out = fleet(FederationConfig::passthrough(), nodes, seed).run(subs);
        assert_eq!(out.steals, 0, "{preset}/{seed}: nothing to steal from yourself");
        assert_same_schedule(&direct, &out.outcomes[0])
            .unwrap_or_else(|e| panic!("{preset}/{seed}: {e}"));
    }
}

/// A fuzzed submission stream sized to one partition: every job fits a
/// `nodes_each`-node instance, so any instance can own any job and the
/// steal pass is always free to migrate.
fn fuzzed_stream(g: &mut Gen, nodes_each: u32) -> Vec<Submission> {
    let n = 8 + g.usize(0, 24);
    let mut t = 0.0;
    let mut subs = Vec::with_capacity(n);
    for i in 0..n {
        t += g.f64(0.05, 2.5);
        let whole = g.usize(0, 2) > 0;
        let request = if whole {
            ResourceRequest::WholeNode
        } else {
            ResourceRequest::Cores { cores: 1u32 << g.int(0, 5), mem_mib: 0 }
        };
        let tasks = 1 + g.usize(0, (nodes_each as usize).saturating_sub(1));
        subs.push(Submission {
            at: t,
            class: if i % 2 == 0 { JobClass::Interactive } else { JobClass::Batch },
            spec: job(
                &format!("fuzz-{i}"),
                tasks,
                request,
                g.f64(0.2, if whole { 6.0 } else { 15.0 }),
            ),
        });
    }
    subs
}

/// Property 2: across random fleets and steal traffic, jobs are
/// conserved — each completes exactly once, on exactly one instance.
#[test]
fn stealing_conserves_jobs_fuzzed() {
    forall("steal conservation", 12, |g| {
        let instances = 2 + g.usize(0, 2);
        let nodes_each = 2 + g.usize(0, 4) as u32;
        let cfg = FederationConfig {
            instances,
            batch: 1 + g.usize(0, 7),
            flush_interval: [0.5, 1.0][g.usize(0, 1)],
            steal_threshold: g.usize(0, 6),
        };
        let subs = fuzzed_stream(g, nodes_each);
        let n_jobs = subs.len();
        let n_tasks: usize = subs.iter().map(|s| s.spec.tasks.len()).sum();
        let seed = g.int(0, u64::MAX - 1);
        let out = fleet(cfg, nodes_each, seed).run(subs);

        if out.jobs.len() != n_jobs {
            return Err(format!("{} jobs in, {} reported", n_jobs, out.jobs.len()));
        }
        if out.unfinished != 0 {
            return Err(format!("{} tasks never finished", out.unfinished));
        }
        let reported_tasks: usize = out.jobs.iter().map(|j| j.tasks).sum();
        if reported_tasks != n_tasks {
            return Err(format!("{n_tasks} tasks in, {reported_tasks} reported"));
        }
        for (i, j) in out.jobs.iter().enumerate() {
            if j.completed != j.tasks {
                return Err(format!("job {i}: {}/{} tasks completed", j.completed, j.tasks));
            }
            if j.owner >= instances {
                return Err(format!("job {i}: owner {} out of range", j.owner));
            }
            if !(j.latency.is_finite() && j.latency >= 0.0) {
                return Err(format!("job {i}: bad latency {}", j.latency));
            }
        }
        let owned: usize = out.instances.iter().map(|r| r.jobs).sum();
        if owned != n_jobs {
            return Err(format!("ownership double-counts: {owned} vs {n_jobs}"));
        }
        let stolen_in: u64 = out.instances.iter().map(|r| r.stolen_in).sum();
        let stolen_out: u64 = out.instances.iter().map(|r| r.stolen_out).sum();
        if stolen_in != stolen_out || stolen_in != out.steals {
            return Err(format!(
                "steal counters diverge: in {stolen_in}, out {stolen_out}, total {}",
                out.steals
            ));
        }
        let hops: u64 = out.jobs.iter().map(|j| j.steals as u64).sum();
        if hops != out.steals {
            return Err(format!("per-job hops {hops} vs fleet steals {}", out.steals));
        }
        Ok(())
    });
}

/// The skewed mix for property 3: three of four partitions pinned by a
/// whole-machine 300 s job, then 160 one-second single-node jobs in one
/// burst. Least-backlog routing can't see the pinned machines (their
/// tasks are *running*, not pending), so without stealing ~3/4 of the
/// burst waits out the blockers.
fn skewed_mix(nodes_each: u32) -> Vec<Submission> {
    let mut subs = Vec::new();
    for b in 0..3 {
        subs.push(Submission {
            at: 0.0,
            class: JobClass::Batch,
            spec: job(
                &format!("blocker-{b}"),
                nodes_each as usize,
                ResourceRequest::WholeNode,
                300.0,
            ),
        });
    }
    for k in 0..160 {
        subs.push(Submission {
            at: 30.0,
            class: JobClass::Interactive,
            spec: job(&format!("short-{k}"), 1, ResourceRequest::WholeNode, 1.0),
        });
    }
    subs
}

/// Property 3: on the skewed mix, enabling work stealing at 4 × 128
/// nodes cuts the short-job p95 launch latency at least in half.
#[test]
fn stealing_improves_skewed_p95() {
    let nodes_each = 128;
    let run = |steal_threshold: usize| {
        let cfg = FederationConfig {
            instances: 4,
            batch: 1,
            flush_interval: 1.0,
            steal_threshold,
        };
        fleet(cfg, nodes_each, 17).run(skewed_mix(nodes_each))
    };
    let stolen = run(4);
    let pinned = run(usize::MAX);
    assert_eq!(pinned.steals, 0, "threshold MAX disables stealing");
    assert!(stolen.steals > 0, "skew must trigger steals");
    assert_eq!(stolen.unfinished, 0);
    assert_eq!(pinned.unfinished, 0);
    let p95_stolen = stolen.class_latency(JobClass::Interactive).p95;
    let p95_pinned = pinned.class_latency(JobClass::Interactive).p95;
    assert!(
        p95_stolen.is_finite() && p95_pinned.is_finite(),
        "both runs must start their shorts ({p95_stolen} vs {p95_pinned})"
    );
    assert!(
        p95_stolen <= p95_pinned / 2.0,
        "stealing must at least halve the skewed p95: {p95_stolen:.1}s vs {p95_pinned:.1}s"
    );
}
