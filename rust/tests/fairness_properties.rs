//! Fairness and noise-robustness properties for the multi-hold,
//! aging-aware backfill layer, end-to-end through the scheduler.
//!
//! Three families of invariants pin the layer down:
//!
//! 1. **Bounded wait** — with aging on (cap wide enough to close any
//!    generated priority gap), no task's launch wait exceeds a bound
//!    computable from the scenario alone, under any generated priority
//!    mix — including the sustained high-priority streams that starve
//!    low-priority whole-node jobs forever under static priorities.
//! 2. **Hold consistency** — at every step the ledger carries at most K
//!    holds, on pairwise distinct nodes, one per task; fuzzed both at
//!    the ledger level (random op sequences) and end-to-end.
//! 3. **Estimate-noise equivalence** — with zero walltime error and
//!    K = 1, the generalized machinery reproduces the single-hold
//!    schedules bit-for-bit (same records, same backfills, same RNG
//!    order), across ≥ 8 generated seeds.
//!
//! Plus the PR-2 starvation regressions: the scenario where a
//! low-priority whole-node job never reaches the queue head now
//! launches within the aging bound — and demonstrably starves with
//! aging off (the pre-aging code path).

use llsched::cluster::Cluster;
use llsched::placement::{FreeIndex, ReservationLedger};
use llsched::scheduler::core::{SchedulerSim, SimOutcome, TaskModel};
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::job::{
    ComputeBatch, JobSpec, ResourceRequest, SchedTaskSpec, TaskState,
};
use llsched::scheduler::noise::NoiseModel;
use llsched::scheduler::queue::AgingPolicy;
use llsched::sim::EventQueue;
use llsched::testing::prop::forall;
use llsched::workload::contention::WalltimeError;

/// Quiet, deterministic sim: no noise, no jitter, unit server speed,
/// backfill on.
fn quiet_sim(nodes: u32, seed: u64) -> SchedulerSim {
    SchedulerSim::new(
        Cluster::tx_green(nodes),
        CostModel::slurm_like_tx_green(),
        NoiseModel::dedicated(),
        seed,
    )
    .with_task_model(TaskModel {
        startup: 0.0,
        jitter_sigma: 0.0,
        p_node_late: 0.0,
        late_range: (0.0, 0.0),
    })
    .with_server_speed(1.0)
    .with_backfill(true)
}

fn job(
    name: &str,
    n_tasks: usize,
    request: ResourceRequest,
    duration: f64,
    priority: i32,
) -> JobSpec {
    let lanes = match request {
        ResourceRequest::WholeNode => 64,
        ResourceRequest::Cores { cores, .. } => cores,
    };
    JobSpec {
        name: name.into(),
        tasks: vec![
            SchedTaskSpec {
                request,
                duration,
                batch: ComputeBatch { count: 1, each: duration },
                lanes,
            };
            n_tasks
        ],
        reservation: None,
        priority,
        preemptable: false,
    }
}

/// The PR-2 starvation scenario: a just-oversubscribed sustained stream
/// of high-priority 48-core tasks (every completion already has a
/// successor pending, so the queue never empties) plus one low-priority
/// whole-node job submitted early. Under static priorities the
/// whole-node job never reaches the queue head, so it never plans a
/// hold and starves until the stream drains (~450 s+). Returns the
/// outcome and the whole-node job's id.
fn starvation_scenario(
    seed: u64,
    holds: usize,
    aging: Option<AgingPolicy>,
) -> (SimOutcome, u64) {
    let mut sim = quiet_sim(2, seed).with_holds(holds).with_aging(aging);
    let mut q = EventQueue::new();
    // Seed backlog so the pending queue is non-empty from the start.
    sim.submit_at(
        &mut q,
        0.5,
        job("seed", 6, ResourceRequest::Cores { cores: 48, mem_mib: 0 }, 10.0, 10),
    );
    // ρ ≈ 1.11: arrivals every 4.5 s versus one 10 s slot per node.
    for k in 0..100u64 {
        sim.submit_at(
            &mut q,
            1.0 + 4.5 * k as f64,
            job(
                &format!("stream-{k}"),
                1,
                ResourceRequest::Cores { cores: 48, mem_mib: 0 },
                10.0,
                10,
            ),
        );
    }
    let batch = sim.submit_at(
        &mut q,
        7.6,
        job("batch", 1, ResourceRequest::WholeNode, 20.0, -5),
    );
    (sim.run(&mut q), batch)
}

fn job_start(out: &SimOutcome, job_id: u64) -> f64 {
    out.records
        .iter()
        .filter(|r| r.job == job_id)
        .map(|r| r.start_t.expect("task started"))
        .fold(f64::NEG_INFINITY, f64::max)
}

/// The acceptance regression: with aging on, the starved whole-node job
/// launches within the aging bound; with aging off (the pre-aging code
/// path, K = 1) the same scenario starves it until the stream drains.
#[test]
fn aging_rescues_whole_node_job_from_priority_starvation() {
    let aged = AgingPolicy::new(0.5, 1000);
    let (on, batch_on) = starvation_scenario(3, 1, Some(aged));
    let (off, batch_off) = starvation_scenario(3, 1, None);
    assert!(on.records.iter().all(|r| r.state == TaskState::Done));
    assert!(off.records.iter().all(|r| r.state == TaskState::Done));
    let on_start = job_start(&on, batch_on);
    let off_start = job_start(&off, batch_off);
    // Crossover analysis: the whole-node job out-ages the stream pool
    // at ~76 s and a hold drains a node within ~10 s; 200 s is triple
    // that. Static priorities starve it until the stream backlog clears
    // (> 450 s of arrivals at ρ > 1).
    assert!(
        on_start < 200.0,
        "aging should launch the whole-node job promptly, started at {on_start}"
    );
    assert!(
        off_start > 330.0,
        "without aging the scenario must starve (regression bait), started at {off_start}"
    );
    assert!(on_start + 60.0 < off_start);
}

/// Multi-hold alone (aging off) also rescues whole-node jobs that are
/// *within the lookahead window*: with K > 1 the planner reserves for
/// blocked whole-node tasks beyond the head, so the job holds a node as
/// soon as any head blocks — the K = 1 discipline never does.
#[test]
fn multi_hold_reserves_beyond_the_queue_head() {
    let (k4, batch_k4) = starvation_scenario(5, 4, None);
    let (k1, batch_k1) = starvation_scenario(5, 1, None);
    assert!(k4.records.iter().all(|r| r.state == TaskState::Done));
    let k4_start = job_start(&k4, batch_k4);
    let k1_start = job_start(&k1, batch_k1);
    assert!(
        k4_start < 120.0,
        "top-K holds should reserve for the deep whole-node job, started at {k4_start}"
    );
    assert!(k1_start > 330.0, "single-hold control must starve, started at {k1_start}");
    assert!(k4.max_active_holds <= 4);
    assert!(!k4.hold_invariant_violated);
}

/// Property (a): bounded wait under aging. The generator produces a
/// saturating high-priority stream (single-occupancy 40/48-core tasks,
/// so every node serves one task at a time and drain arguments are
/// airtight) plus low-priority whole-node jobs. With slope σ and an
/// effectively-uncapped boost, a task that has waited (Δmax+2)/σ
/// outranks every strictly-younger arrival forever, so its wait is
/// bounded by the aging time plus the serialized drain of the tasks
/// at-or-before it — all computable from the scenario.
#[test]
fn bounded_wait_under_aging_property() {
    const SLOPE: f64 = 2.0;
    const D_MAX: f64 = 30.0; // longest generated duration
    const GAP_WAIT: f64 = 17.0 / SLOPE; // (Δmax + 2)/σ, Δmax = 15
    forall("aging bounds every wait", 8, |g| {
        let nodes = 2 + g.usize(0, 2) as u32;
        let seed = g.int(0, u64::MAX - 1);
        let mut sim = quiet_sim(nodes, seed)
            .with_holds(1 + g.usize(0, 3))
            .with_aging(Some(AgingPolicy::new(SLOPE, 1_000_000)));
        let mut q = EventQueue::new();
        // High-priority stream: one task per job, one task per node at
        // a time (40/48 cores on 64-core nodes), every 2.5 s.
        let n_stream = 40 + g.usize(0, 60);
        for i in 0..n_stream {
            let cores = if g.chance(0.5) { 40 } else { 48 };
            sim.submit_at(
                &mut q,
                1.0 + 2.5 * i as f64,
                job(
                    &format!("stream-{i}"),
                    1,
                    ResourceRequest::Cores { cores, mem_mib: 0 },
                    g.f64(5.0, 12.0),
                    g.int(5, 10) as i32,
                ),
            );
        }
        // Low-priority whole-node jobs early in the stream.
        let n_whole = 1 + g.usize(0, 2);
        for i in 0..n_whole {
            sim.submit_at(
                &mut q,
                5.2 + 2.5 * i as f64,
                job(
                    &format!("whole-{i}"),
                    1 + g.usize(0, 1),
                    ResourceRequest::WholeNode,
                    g.f64(10.0, D_MAX),
                    g.int(0, 5) as i32 - 5,
                ),
            );
        }
        let out = sim.run(&mut q);
        if !out.records.iter().all(|r| r.state == TaskState::Done) {
            return Err("run did not drain".into());
        }
        if out.hold_invariant_violated {
            return Err("hold invariants violated".into());
        }
        // Per-task bound: aging time + serialized drain of every task
        // submitted before the aging gap closed (+ service slack), with
        // a 1.5× safety factor — loose, but far below the static-
        // priority starvation horizon for the early whole-node jobs.
        for r in &out.records {
            let start = r.start_t.ok_or("task never started")?;
            let wait = start - r.submit_t;
            let older = out
                .records
                .iter()
                .filter(|o| o.submit_t <= r.submit_t + 7.5 + 1e-9)
                .count();
            let bound =
                1.5 * (GAP_WAIT + (older as f64 + 1.0) * (D_MAX + 5.0) + 2.0 * D_MAX + 30.0);
            if wait > bound {
                return Err(format!(
                    "task {} (job {}) waited {wait:.1} s > bound {bound:.1} s",
                    r.task, r.job
                ));
            }
        }
        Ok(())
    });
}

/// Property (b), ledger level: random operation sequences never break
/// the hold invariants — at most K holds, pairwise-distinct nodes, one
/// hold per task — and `set_hold`'s acceptance implies the hold landed.
#[test]
fn hold_consistency_under_random_ledger_ops() {
    forall("ledger hold invariants", 40, |g| {
        let n = 2 + g.usize(0, 6);
        let k = 1 + g.usize(0, 4);
        let cluster = Cluster::tx_green(n as u32);
        let index = FreeIndex::build(&cluster);
        let mut ledger = ReservationLedger::new(n);
        ledger.set_max_holds(k);
        let mut now = 0.0f64;
        for step in 0..120 {
            now += g.f64(0.0, 5.0);
            let node = g.usize(0, n - 1) as u32;
            let task = g.int(0, 9);
            match g.usize(0, 4) {
                0 => ledger.note_start(node, now + g.f64(1.0, 50.0)),
                1 => ledger.note_release(node),
                2 => {
                    let accepted = ledger.set_hold(task, node, now + g.f64(0.0, 30.0));
                    if accepted && ledger.hold_for(task).map(|h| h.node) != Some(node) {
                        return Err(format!("accepted hold for {task} not installed"));
                    }
                }
                3 => ledger.clear_hold(task),
                _ => {
                    if let Some((planned, start)) =
                        ledger.plan_whole_node(&index, &cluster, 0, now, task)
                    {
                        // A planned node is never another task's fence.
                        if ledger.hold_on(planned).map(|h| h.task != task).unwrap_or(false) {
                            return Err(format!("planner proposed a fenced node {planned}"));
                        }
                        let _ = ledger.set_hold(task, planned, start);
                    }
                }
            }
            ledger
                .check_invariants()
                .map_err(|e| format!("step {step}: {e}"))?;
            if ledger.holds().len() > k {
                return Err(format!("{} holds exceed K = {k}", ledger.holds().len()));
            }
            for (i, a) in ledger.holds().iter().enumerate() {
                for b in &ledger.holds()[i + 1..] {
                    if a.node == b.node || a.task == b.task {
                        return Err(format!("overlapping holds {a:?} / {b:?}"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Property (b), end-to-end, plus the no-stall guarantee under noise:
/// random mixes with random K, aging, and walltime error always drain,
/// never exceed K simultaneous holds, and never overlap holds.
#[test]
fn fairness_and_noise_invariants_end_to_end() {
    forall("fairness/noise invariants", 12, |g| {
        let nodes = 2 + g.usize(0, 3) as u32;
        let seed = g.int(0, u64::MAX - 1);
        let k = 1 + g.usize(0, 4);
        let aging = if g.chance(0.5) {
            Some(AgingPolicy::new(g.f64(0.1, 2.0), 1000))
        } else {
            None
        };
        let error = match g.usize(0, 2) {
            0 => WalltimeError::None,
            1 => WalltimeError::LogNormal { sigma: g.f64(0.1, 0.8) },
            _ => WalltimeError::Uniform { frac: g.f64(0.1, 0.9) },
        };
        let mut sim = quiet_sim(nodes, seed)
            .with_holds(k)
            .with_aging(aging)
            .with_walltime_error(error);
        let mut q = EventQueue::new();
        let batch_jobs = 1 + g.usize(0, 2);
        for i in 0..batch_jobs {
            // Snapped between the small-stream arrival/registration
            // windows (grid 1.0 + 1.25k, ~0.5 s registrations), and
            // spaced ≥ 7.5 s apart from each other, so submissions do
            // not pile into TICK-granularity retries.
            sim.submit_at(
                &mut q,
                0.3 + 2.5 * (g.usize(0, 2) + 3 * i) as f64,
                job(
                    &format!("batch-{i}"),
                    1 + g.usize(0, nodes as usize),
                    ResourceRequest::WholeNode,
                    g.f64(20.0, 90.0),
                    g.int(0, 4) as i32 - 4,
                ),
            );
        }
        let n_small = 5 + g.usize(0, 30);
        for i in 0..n_small {
            let cores = 1u32 << g.int(0, 5); // 1..32
            sim.submit_at(
                &mut q,
                1.0 + 1.25 * i as f64,
                job(
                    &format!("small-{i}"),
                    1 + g.usize(0, 3),
                    ResourceRequest::Cores { cores, mem_mib: 0 },
                    g.f64(1.0, 15.0),
                    g.int(0, 10) as i32,
                ),
            );
        }
        let out = sim.run(&mut q);
        if !out.records.iter().all(|r| r.state == TaskState::Done) {
            return Err("noisy estimates wedged the run".into());
        }
        if out.hold_invariant_violated {
            return Err("hold invariants violated".into());
        }
        if out.max_active_holds > k {
            return Err(format!("{} holds exceed K = {k}", out.max_active_holds));
        }
        Ok(())
    });
}

/// Property (c): estimate-noise equivalence. With K = 1 and zero
/// walltime error, the generalized machinery must reproduce the
/// single-hold schedule bit-for-bit — both through the exact-oracle
/// path (`WalltimeError::None`, the literal PR-2 code path) and through
/// the noisy-estimate path at zero width (`Uniform { frac: 0.0 }`,
/// which samples factors of exactly 1.0 from the independent estimate
/// stream). 12 generated seeds (≥ the 8 the acceptance bar asks for).
#[test]
fn zero_noise_single_hold_reproduces_legacy_schedules() {
    forall("K=1/zero-noise equivalence", 12, |g| {
        let nodes = 2 + g.usize(0, 3) as u32;
        let seed = g.int(0, u64::MAX - 1);
        // One shared submission plan, replayed against each variant.
        // Batch arrival snapped between the small-stream registration
        // windows (see the fairness invariants test).
        let batch = (
            0.3 + 2.5 * g.usize(0, 5) as f64,
            job(
                "batch",
                1 + g.usize(0, 2 * nodes as usize),
                ResourceRequest::WholeNode,
                g.f64(20.0, 80.0),
                0,
            ),
        );
        let mut subs: Vec<(f64, JobSpec)> = vec![batch];
        let n_small = 5 + g.usize(0, 20);
        for i in 0..n_small {
            let cores = 1u32 << g.int(0, 5);
            subs.push((
                1.0 + 1.25 * i as f64,
                job(
                    &format!("small-{i}"),
                    1 + g.usize(0, 2),
                    ResourceRequest::Cores { cores, mem_mib: 0 },
                    g.f64(1.0, 12.0),
                    g.int(0, 10) as i32,
                ),
            ));
        }
        let run = |mut sim: SchedulerSim| -> SimOutcome {
            let mut q = EventQueue::new();
            for (at, spec) in &subs {
                sim.submit_at(&mut q, *at, spec.clone());
            }
            sim.run(&mut q)
        };
        let legacy = run(quiet_sim(nodes, seed));
        let explicit = run(
            quiet_sim(nodes, seed)
                .with_holds(1)
                .with_aging(None)
                .with_walltime_error(WalltimeError::None),
        );
        let zero_noise = run(
            quiet_sim(nodes, seed)
                .with_holds(1)
                .with_walltime_error(WalltimeError::Uniform { frac: 0.0 }),
        );
        for (label, other) in [("explicit", &explicit), ("zero-noise", &zero_noise)] {
            if other.records.len() != legacy.records.len() {
                return Err(format!("{label}: record count diverged"));
            }
            for (a, b) in legacy.records.iter().zip(&other.records) {
                if a.state != b.state
                    || a.start_t != b.start_t
                    || a.end_t != b.end_t
                    || a.cleanup_t != b.cleanup_t
                    || a.cores != b.cores
                {
                    return Err(format!(
                        "{label}: task {} diverged: {a:?} vs {b:?}",
                        a.task
                    ));
                }
            }
            if legacy.backfills.len() != other.backfills.len() {
                return Err(format!("{label}: backfill count diverged"));
            }
            for (a, b) in legacy.backfills.iter().zip(&other.backfills) {
                if a.task != b.task || a.node != b.node || a.time != b.time {
                    return Err(format!("{label}: backfill diverged: {a:?} vs {b:?}"));
                }
            }
            if legacy.events_processed != other.events_processed {
                return Err(format!("{label}: event count diverged"));
            }
        }
        Ok(())
    });
}
