//! Flight-recorder property suite.
//!
//! The recorder ([`llsched::obs`]) promises three things, in order:
//!
//! 1. **Off is free.** With `trace_cap = 0` no recorder exists and the
//!    outcome carries no snapshot — the schedule is the historical one
//!    (the bit-for-bit pin is `rust/tests/event_equivalence.rs`; here
//!    we pin the absence of the snapshot and of timeline recording
//!    under `without_timeline`).
//! 2. **On is invisible.** The recorder only observes — recorder-on
//!    runs produce the identical schedule, span, per-class quantiles,
//!    pool ledger, and fault counters as recorder-off runs of the same
//!    seed.
//! 3. **Deterministic bytes.** Same-seed recorder-on runs export
//!    byte-identical Perfetto JSON and decision logs, across every
//!    churn preset and through the federated gateway.

use llsched::cluster::Cluster;
use llsched::coordinator::experiment::{
    run_contention_federated, run_contention_with, ContentionOpts,
};
use llsched::fault::scenario::ChurnScenario;
use llsched::fault::FaultConfig;
use llsched::federation::FederationConfig;
use llsched::obs::{decision_log, perfetto_json, Subsystem, TraceKind};
use llsched::pool::PoolConfig;
use llsched::scheduler::core::SchedulerSim;
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::job::{ComputeBatch, JobSpec, ResourceRequest, SchedTaskSpec};
use llsched::scheduler::noise::NoiseModel;
use llsched::sim::EventQueue;
use llsched::workload::contention::ContentionMix;

const CHURN_PRESETS: [&str; 4] = ["churn_mtbf", "churn_reclaim", "churn_drain", "churn_full"];

/// The `churn`/`trace` commands' cluster-scaled elastic pool defaults.
fn pooled(nodes: u32) -> PoolConfig {
    let n = nodes.max(2) as usize;
    PoolConfig {
        size: (n / 4).max(1),
        min: (n / 8).min((n / 4).max(1)),
        max: (3 * n / 4).max((n / 4).max(1)),
        ..PoolConfig::disabled()
    }
}

/// Property 2: the recorder observes, it never steers. A pooled burst
/// run and a pooled churn run must produce the identical schedule with
/// the recorder on and off.
#[test]
fn recorder_on_never_steers_the_schedule() {
    for (preset, nodes, seed) in [("burst", 32u32, 7u64), ("churn_full", 32, 11)] {
        let (mix, fault) = if preset.starts_with("churn_") {
            let sc = ChurnScenario::preset(preset, nodes).unwrap();
            (sc.mix, sc.fault)
        } else {
            (
                ContentionMix::preset(preset, nodes).unwrap(),
                FaultConfig::disabled(),
            )
        };
        let opts = |cap: usize| ContentionOpts {
            pool: pooled(nodes),
            fault: fault.clone(),
            trace_cap: cap,
            ..ContentionOpts::classic(true, seed)
        };
        let off = run_contention_with(&mix, opts(0)).unwrap();
        let on = run_contention_with(&mix, opts(1 << 16)).unwrap();
        assert!(off.obs.is_none(), "{preset}: trace_cap 0 must not record");
        let snap = on.obs.as_ref().expect("recorder-on run carries a snapshot");
        assert!(snap.total_events() > 0, "{preset}: a pooled run records decisions");
        assert_eq!(off.span.to_bits(), on.span.to_bits(), "{preset}: span diverged");
        assert_eq!(off.backfills, on.backfills, "{preset}: backfills diverged");
        assert_eq!(off.unfinished, on.unfinished, "{preset}: unfinished diverged");
        assert_eq!(
            off.max_active_holds, on.max_active_holds,
            "{preset}: hold peak diverged"
        );
        assert_eq!(
            off.overdue_preemptions, on.overdue_preemptions,
            "{preset}: preemptions diverged"
        );
        for (a, b) in off.reports.iter().zip(&on.reports) {
            assert_eq!(
                a.median_launch_latency.to_bits(),
                b.median_launch_latency.to_bits(),
                "{preset}: median latency diverged"
            );
            assert_eq!(
                a.p95_launch_latency.to_bits(),
                b.p95_launch_latency.to_bits(),
                "{preset}: p95 latency diverged"
            );
            assert_eq!(
                a.core_seconds.to_bits(),
                b.core_seconds.to_bits(),
                "{preset}: core-seconds diverged"
            );
            assert_eq!(a.completed, b.completed, "{preset}: completions diverged");
        }
        let (po, pn) = (off.pool.as_ref().unwrap(), on.pool.as_ref().unwrap());
        assert_eq!(po.launches, pn.launches, "{preset}: pool launches diverged");
        assert_eq!(po.grows, pn.grows, "{preset}: pool grows diverged");
        assert_eq!(po.shrinks, pn.shrinks, "{preset}: pool shrinks diverged");
        assert_eq!(po.peak_leased, pn.peak_leased, "{preset}: pool peak diverged");
        match (&off.fault, &on.fault) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                assert_eq!(a.stats.node_failures, b.stats.node_failures);
                assert_eq!(a.stats.tasks_killed, b.stats.tasks_killed);
                assert_eq!(a.stats.tasks_requeued, b.stats.tasks_requeued);
            }
            _ => panic!("{preset}: fault outcome presence diverged"),
        }
    }
}

/// Property 3: same-seed exports are byte-identical — across all four
/// churn presets and through the federated gateway.
#[test]
fn same_seed_trace_exports_are_byte_identical() {
    for preset in CHURN_PRESETS {
        let sc = ChurnScenario::preset(preset, 32).unwrap();
        let opts = || ContentionOpts {
            pool: pooled(32),
            fault: sc.fault.clone(),
            trace_cap: 8192,
            ..ContentionOpts::classic(true, 5)
        };
        let a = run_contention_with(&sc.mix, opts()).unwrap().obs.unwrap();
        let b = run_contention_with(&sc.mix, opts()).unwrap().obs.unwrap();
        assert_eq!(
            perfetto_json(&a, None).to_pretty(),
            perfetto_json(&b, None).to_pretty(),
            "{preset}: perfetto bytes diverged"
        );
        assert_eq!(
            decision_log(&a, None),
            decision_log(&b, None),
            "{preset}: decision-log bytes diverged"
        );
    }
    let mix = ContentionMix::preset("burst", 32).unwrap();
    let fed = FederationConfig {
        instances: 2,
        ..FederationConfig::default()
    };
    let opts = || ContentionOpts {
        pool: pooled(16),
        trace_cap: 8192,
        ..ContentionOpts::classic(true, 9)
    };
    let a = run_contention_federated(&mix, opts(), fed).unwrap().obs.unwrap();
    let b = run_contention_federated(&mix, opts(), fed).unwrap().obs.unwrap();
    assert_eq!(
        perfetto_json(&a, None).to_pretty(),
        perfetto_json(&b, None).to_pretty(),
        "federated: perfetto bytes diverged"
    );
    assert_eq!(
        decision_log(&a, None),
        decision_log(&b, None),
        "federated: decision-log bytes diverged"
    );
}

/// The acceptance scenario: a recorder-on federated burst run exports a
/// Perfetto-shaped document with events from at least four subsystems,
/// one process lane per instance plus one for the gateway.
#[test]
fn federated_burst_trace_covers_four_subsystems() {
    let mix = ContentionMix::preset("burst", 64).unwrap();
    let fed = FederationConfig {
        instances: 2,
        ..FederationConfig::default()
    };
    let opts = ContentionOpts {
        pool: pooled(32),
        trace_cap: 1 << 16,
        ..ContentionOpts::classic(true, 7)
    };
    let res = run_contention_federated(&mix, opts, fed).unwrap();
    let snap = res.obs.as_ref().expect("traced federated run carries a snapshot");
    let seen = snap.subsystems_seen();
    for sub in [
        Subsystem::Scheduler,
        Subsystem::Backfill,
        Subsystem::Pool,
        Subsystem::Federation,
    ] {
        assert!(seen.contains(&sub), "missing {sub:?} events; saw {seen:?}");
    }
    assert!(seen.len() >= 4, "expected >= 4 subsystems, saw {seen:?}");
    // Instance lanes 0 and 1, gateway lane 2.
    for pid in 0..=2u32 {
        assert!(
            snap.events.iter().any(|e| e.pid == pid),
            "no events on process lane {pid}"
        );
    }
    let text = perfetto_json(snap, None).to_pretty();
    assert!(text.starts_with('{'), "perfetto export is one JSON object");
    for key in [
        "\"traceEvents\":",
        "\"process_name\"",
        "\"thread_name\"",
        "\"ph\": \"i\"",
        "\"metadata\":",
    ] {
        assert!(text.contains(key), "perfetto export missing {key}");
    }
    // A subsystem filter keeps exactly that subsystem's vocabulary.
    let pool_only = decision_log(snap, Some(&[Subsystem::Pool]));
    assert!(pool_only.contains("pool_dispatch"), "pool filter keeps pool events");
    assert!(
        !pool_only.contains("gateway_route") && !pool_only.contains(" pick "),
        "pool filter drops other subsystems"
    );
}

/// The ring is a bounded window: a small cap keeps at most `cap`
/// records and counts what it overwrote, while the registry still
/// counts everything — capacity changes retention, never observation.
#[test]
fn ring_cap_bounds_retention_and_counts_drops() {
    let mix = ContentionMix::preset("burst", 32).unwrap();
    let opts = |cap: usize| ContentionOpts {
        pool: pooled(32),
        trace_cap: cap,
        ..ContentionOpts::classic(true, 3)
    };
    let small = run_contention_with(&mix, opts(64)).unwrap().obs.unwrap();
    assert!(small.events.len() <= 64, "ring respects its capacity");
    assert!(small.dropped > 0, "a burst run overflows a 64-slot ring");
    assert_eq!(
        small.total_events(),
        small.events.len() as u64 + small.dropped,
        "registry total = retained + dropped"
    );
    let big = run_contention_with(&mix, opts(1 << 20)).unwrap().obs.unwrap();
    assert_eq!(big.dropped, 0, "a huge ring drops nothing");
    assert_eq!(
        big.total_events(),
        small.total_events(),
        "capacity changes retention, not what was observed"
    );
    assert_eq!(
        &big.events[big.events.len() - small.events.len()..],
        &small.events[..],
        "the small ring keeps exactly the latest window"
    );
}

/// Every retained record respects the documented vocabulary, and the
/// injected host clock makes the single-recorder stream strictly
/// ordered.
#[test]
fn recorded_events_respect_the_vocabulary() {
    let sc = ChurnScenario::preset("churn_full", 32).unwrap();
    let opts = ContentionOpts {
        pool: pooled(32),
        fault: sc.fault.clone(),
        trace_cap: 1 << 18,
        ..ContentionOpts::classic(true, 13)
    };
    let snap = run_contention_with(&sc.mix, opts).unwrap().obs.unwrap();
    assert!(snap.subsystems_seen().contains(&Subsystem::Fault), "churn records cascades");
    for ev in &snap.events {
        assert!(ev.t >= 0.0, "simulated time is non-negative");
        match ev.kind {
            TraceKind::Pick => assert!(ev.unit <= 13, "pick branch code in range: {}", ev.unit),
            TraceKind::RegisterRoute => {
                assert!(ev.detail == 0 || ev.detail == 1, "route detail is pool/batch")
            }
            TraceKind::FaultCascade => {
                assert!((0..=4).contains(&ev.detail), "cascade step code in range")
            }
            _ => {}
        }
    }
    assert!(
        snap.events.windows(2).all(|w| w[0].host_ns < w[1].host_ns),
        "one recorder's stream is strictly host-clock ordered"
    );
}

/// Property 1's timeline half: `without_timeline()` must leave the
/// utilization series provably empty even on the pool dispatch/release
/// paths (which push their own occupancy deltas) — and stripping the
/// timeline must not change the schedule.
#[test]
fn without_timeline_stays_empty_on_pool_paths() {
    let short = |name: &str| JobSpec {
        name: name.into(),
        tasks: vec![SchedTaskSpec {
            request: ResourceRequest::WholeNode,
            duration: 2.0,
            batch: ComputeBatch { count: 1, each: 2.0 },
            lanes: 64,
        }],
        reservation: None,
        priority: 0,
        preemptable: false,
    };
    let run = |strip: bool| {
        let mut sim = SchedulerSim::new(
            Cluster::tx_green(4),
            CostModel::slurm_like_tx_green(),
            NoiseModel::dedicated(),
            9,
        )
        .with_backfill(true)
        .with_pool(PoolConfig { size: 2, min: 1, max: 3, ..PoolConfig::sized(2) });
        if strip {
            sim = sim.without_timeline();
        }
        let mut q = EventQueue::new();
        for i in 0..8 {
            sim.submit_at(&mut q, 0.5 + 0.7 * f64::from(i), short(&format!("short-{i}")));
        }
        sim.run(&mut q)
    };
    let with = run(false);
    let without = run(true);
    assert!(
        with.pool.as_ref().is_some_and(|p| p.launches > 0),
        "the workload exercises the pool dispatch path"
    );
    assert!(!with.timeline.is_empty(), "timeline recording is on by default");
    assert!(without.timeline.is_empty(), "without_timeline() must record nothing");
    assert_eq!(
        with.final_time.to_bits(),
        without.final_time.to_bits(),
        "stripping the timeline must not change the schedule"
    );
    assert_eq!(with.events_processed, without.events_processed);
}

/// Opt-in self-profiling accumulates `pick_next` invocations and the
/// simulated charge; it must not disturb the trace itself.
#[test]
fn self_profiling_accumulates_pick_timings() {
    let mix = ContentionMix::preset("tiny", 8).unwrap();
    let opts = |profile: bool| ContentionOpts {
        trace_cap: 4096,
        trace_profile: profile,
        ..ContentionOpts::classic(true, 3)
    };
    let plain = run_contention_with(&mix, opts(false)).unwrap().obs.unwrap();
    assert!(plain.profile.is_none(), "profiling is opt-in");
    let profiled = run_contention_with(&mix, opts(true)).unwrap().obs.unwrap();
    let p = profiled.profile.expect("profiling on");
    assert!(p.picks > 0, "picks were timed");
    assert!(p.sim_cost_s > 0.0, "simulated charge accumulated");
    assert_eq!(
        decision_log(&plain, None),
        decision_log(&profiled, None),
        "profiling must not change the recorded decisions"
    );
}
