//! End-to-end integration: launch tools → scheduler → metrics → reports,
//! plus the paper-shape assertions that tie the whole reproduction
//! together at reduced scale.

use llsched::aggregation::plan::ClusterShape;
use llsched::aggregation::triples::Triple;
use llsched::cluster::Cluster;
use llsched::config::presets::TASK_CONFIGS;
use llsched::config::Mode;
use llsched::coordinator::experiment::{run_cell, run_matrix, ExperimentOpts};
use llsched::lltools::{LLMapReduce, LLsub};
use llsched::metrics::report;
use llsched::scheduler::core::SchedulerSim;
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::noise::NoiseModel;
use llsched::workload::paper::PaperCell;
use llsched::aggregation::plan::Workload;

#[test]
fn llsub_triples_flow_through_scheduler() {
    let shape = ClusterShape { nodes: 4, cores_per_node: 64, task_mem_mib: 64 };
    let sub = LLsub::new("./sim_task", 5.0)
        .triples(&Triple::fill(4, 64), &shape)
        .unwrap();
    let sim = SchedulerSim::new(
        Cluster::tx_green(4),
        CostModel::slurm_like_tx_green(),
        NoiseModel::dedicated(),
        3,
    )
    .with_server_speed(1.0);
    let (out, job) = sim.run_single(sub.job);
    let stats = out.job_stats(job, 5.0).unwrap();
    assert_eq!(stats.array_size, 4);
    assert!(stats.runtime < 10.0, "runtime {}", stats.runtime);
    // Generated scripts really do cover 4 × 64 workers.
    let total: u64 = sub.scripts.iter().map(|s| s.total_tasks()).sum();
    assert_eq!(total, 256);
}

#[test]
fn llmapreduce_mimo_vs_triples_same_work_different_array() {
    let shape = ClusterShape { nodes: 8, cores_per_node: 64, task_mem_mib: 64 };
    let w = Workload::Uniform { count: 8 * 64 * 4, duration: 2.0 };
    let mimo = LLMapReduce::new("mapper").map(&w, &shape).unwrap();
    let trip = LLMapReduce::new("mapper").with_triples().map(&w, &shape).unwrap();
    assert_eq!(mimo.job.array_size(), 512);
    assert_eq!(trip.job.array_size(), 8);
    // Scheduler-visible load ratio = cores per node (the paper's lever).
    assert_eq!(mimo.job.array_size() / trip.job.array_size(), 64);
}

#[test]
fn paper_shape_holds_at_small_scale() {
    // The qualitative claims, at 32 nodes (fast to simulate):
    // N* overhead < 10% T_job; M* overhead > 10%; N* fills faster.
    let t = TASK_CONFIGS[3];
    let n = run_cell(&PaperCell::new(32, t, Mode::NodeBased, 0)).unwrap();
    let m = run_cell(&PaperCell::new(32, t, Mode::MultiLevel, 0)).unwrap();
    assert!(n.overhead / 240.0 < 0.10, "N* norm overhead {}", n.overhead / 240.0);
    assert!(m.overhead / 240.0 > 0.10, "M* norm overhead {}", m.overhead / 240.0);
    assert!(n.dispatch_span < m.dispatch_span / 10.0);
    // Both reach full utilization at this scale (paper Fig 2, S1).
    assert!(n.utilization.peak() > 0.99);
    assert!(m.utilization.peak() > 0.99);
}

#[test]
fn overhead_roughly_independent_of_task_time() {
    // Paper: "the overhead time remains at the same level regardless of
    // the task times ... as long as the configuration size is kept the
    // same" — because the scheduling-task count is fixed per mode.
    let mut overheads = Vec::new();
    for t in &TASK_CONFIGS {
        let m = run_cell(&PaperCell::new(32, *t, Mode::MultiLevel, 1)).unwrap();
        overheads.push(m.overhead);
    }
    let min = overheads.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = overheads.iter().cloned().fold(0.0, f64::max);
    assert!(
        max < 3.0 * min.max(10.0),
        "overheads vary too much with t: {overheads:?}"
    );
}

#[test]
fn matrix_reports_render() {
    let opts = ExperimentOpts { max_nodes: 64, runs: 1, ..Default::default() };
    let (points, all) = run_matrix(&opts, |_| {}).unwrap();
    let t3 = report::table3(&points);
    assert!(t3.contains("32 nodes") && t3.contains("64 nodes"));
    assert!(t3.contains("N/A"), "512-node rows unmeasured here");
    let f1 = report::fig1_csv(&points);
    assert_eq!(f1.as_str().lines().count(), points.len() + 1);
    let med: Vec<_> = llsched::coordinator::experiment::median_runs(&all);
    assert_eq!(med.len(), points.len());
    let series: Vec<(String, llsched::metrics::timeline::UtilizationSeries)> = med
        .iter()
        .map(|r| {
            (
                llsched::coordinator::experiment::fig2_label(&r.cell),
                r.utilization.clone(),
            )
        })
        .collect();
    let f2 = report::fig2_csv(&series);
    assert!(f2.as_str().lines().count() > 100);
}

#[test]
fn release_span_grows_with_array_size() {
    // Paper: "releasing the completed tasks takes significantly longer
    // as compared to dispatching" at scale. Compare release spans.
    let t = TASK_CONFIGS[3];
    let m64 = run_cell(&PaperCell::new(64, t, Mode::MultiLevel, 0)).unwrap();
    let m256 = run_cell(&PaperCell::new(256, t, Mode::MultiLevel, 0)).unwrap();
    assert!(
        m256.release_span > 2.0 * m64.release_span,
        "release spans {} vs {}",
        m64.release_span,
        m256.release_span
    );
    // And node-based release is far cheaper at the same scale.
    let n256 = run_cell(&PaperCell::new(256, t, Mode::NodeBased, 0)).unwrap();
    assert!(n256.release_span * 10.0 < m256.release_span);
}

#[test]
fn spot_release_headline() {
    // Node-based spot jobs release ~an order of magnitude faster.
    let core = llsched::spot::measure_release(Mode::MultiLevel, 32, 64, 60.0, 5).unwrap();
    let node = llsched::spot::measure_release(Mode::NodeBased, 32, 64, 60.0, 5).unwrap();
    assert_eq!(core.sched_tasks / node.sched_tasks, 64);
    assert!(node.release_latency * 20.0 < core.release_latency);
}

#[test]
fn guard_marks_512_multilevel_unusable() {
    // The paper could not run M* at 512 nodes in production; our
    // responsiveness guard reproduces the distinction.
    let t = TASK_CONFIGS[3];
    let m = run_cell(&PaperCell::new(512, t, Mode::MultiLevel, 0)).unwrap();
    assert!(m.unusable_in_production, "M* 512 saturates the scheduler");
    assert!(m.runtime > 2000.0, "the collapse: {}", m.runtime);
    let n = run_cell(&PaperCell::new(512, t, Mode::NodeBased, 0)).unwrap();
    assert!(!n.unusable_in_production, "N* stays responsive");
    // Paper: M* 512 never reaches 100% utilization.
    assert!(m.utilization.peak() < 1.0);
    assert!(m.utilization.peak() < 0.90, "peak {}", m.utilization.peak());
}
