//! Fault & churn property suite.
//!
//! The fault layer (see `docs/audit-log.md` and `docs/scenarios.md`)
//! makes three promises this suite pins down:
//!
//! 1. **Fault-off is bit-for-bit free** — a sim built with
//!    `FaultConfig::disabled()` produces the exact schedule (records,
//!    event counts, busy breakdown, pool ledger) of a sim that never
//!    heard of faults. Enabling the subsystem without enabling any
//!    churn process must not perturb a single decision.
//! 2. **Churn conserves tasks** — every task killed by a node failure
//!    is either requeued or declared lost (`tasks_killed ==
//!    tasks_requeued + tasks_lost`), nothing is silently dropped, and
//!    under deterministic-recovery churn (reclamation/drain windows
//!    whose holds land inside the horizon) every task still finishes.
//!    The audit log is coherent with the counters: one record per
//!    counted event.
//! 3. **Replay determinism** — same `(scenario, seed)` twice yields a
//!    byte-identical audit log (`AuditLog::to_text`) and an identical
//!    schedule, on every churn preset, with the pool fleet enabled.
//!    This is the contract `churn --replay` checks in CI.

use llsched::cluster::Cluster;
use llsched::coordinator::experiment::{run_contention_with, ContentionOpts};
use llsched::fault::audit::{AuditEvent, AuditLog};
use llsched::fault::scenario::{ChurnScenario, CHURN_PRESETS};
use llsched::fault::{FaultConfig, RetryPolicy};
use llsched::pool::PoolConfig;
use llsched::scheduler::core::{SchedulerSim, SimOutcome, TaskModel};
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::job::{ComputeBatch, JobSpec, ResourceRequest, SchedTaskSpec, TaskState};
use llsched::scheduler::noise::NoiseModel;
use llsched::sim::EventQueue;
use llsched::testing::prop::forall;

fn quiet_sim(nodes: u32, seed: u64) -> SchedulerSim {
    SchedulerSim::new(
        Cluster::tx_green(nodes),
        CostModel::slurm_like_tx_green(),
        NoiseModel::dedicated(),
        seed,
    )
    .with_task_model(TaskModel {
        startup: 0.0,
        jitter_sigma: 0.0,
        p_node_late: 0.0,
        late_range: (0.0, 0.0),
    })
    .with_server_speed(1.0)
    .with_backfill(true)
}

fn job(
    name: &str,
    n_tasks: usize,
    request: ResourceRequest,
    duration: f64,
    priority: i32,
) -> JobSpec {
    let lanes = match request {
        ResourceRequest::WholeNode => 64,
        ResourceRequest::Cores { cores, .. } => cores,
    };
    JobSpec {
        name: name.into(),
        tasks: vec![
            SchedTaskSpec {
                request,
                duration,
                batch: ComputeBatch { count: 1, each: duration },
                lanes,
            };
            n_tasks
        ],
        reservation: None,
        priority,
        preemptable: false,
    }
}

/// A fuzzed workload with enough long-running whole-node work that a
/// mid-run churn window has something to kill, plus a stream of small
/// jobs contending around it.
fn fuzzed_subs(g: &mut llsched::testing::prop::Gen, nodes: u32) -> Vec<(f64, JobSpec)> {
    let mut subs: Vec<(f64, JobSpec)> = vec![(
        0.5 + g.f64(0.0, 4.0),
        job(
            "batch",
            1 + g.usize(0, nodes as usize),
            ResourceRequest::WholeNode,
            g.f64(40.0, 120.0),
            0,
        ),
    )];
    let n_small = 4 + g.usize(0, 10);
    for i in 0..n_small {
        let whole = g.usize(0, 2) > 0;
        let request = if whole {
            ResourceRequest::WholeNode
        } else {
            ResourceRequest::Cores { cores: 1u32 << g.int(0, 5), mem_mib: 0 }
        };
        subs.push((
            1.0 + 2.3 * i as f64,
            job(
                &format!("small-{i}"),
                1 + g.usize(0, 3),
                request,
                g.f64(0.5, if whole { 15.0 } else { 8.0 }),
                g.int(0, 10) as i32,
            ),
        ));
    }
    subs
}

fn run_sim(mut sim: SchedulerSim, subs: &[(f64, JobSpec)]) -> SimOutcome {
    let mut q = EventQueue::new();
    for (at, spec) in subs {
        sim.submit_at(&mut q, *at, spec.clone());
    }
    sim.run(&mut q)
}

/// Assert two outcomes are the same schedule, bit for bit.
fn assert_same_schedule(a: &SimOutcome, b: &SimOutcome, what: &str) -> Result<(), String> {
    if a.records.len() != b.records.len() {
        return Err(format!("{what}: record count diverged"));
    }
    for (x, y) in a.records.iter().zip(&b.records) {
        if x.state != y.state
            || x.start_t != y.start_t
            || x.end_t != y.end_t
            || x.cleanup_t != y.cleanup_t
            || x.cores != y.cores
        {
            return Err(format!("{what}: task {} diverged: {x:?} vs {y:?}", x.task));
        }
    }
    if a.events_processed != b.events_processed {
        return Err(format!(
            "{what}: event count diverged ({} vs {})",
            a.events_processed, b.events_processed
        ));
    }
    if a.final_time != b.final_time {
        return Err(format!("{what}: final time diverged"));
    }
    if a.busy.total() != b.busy.total() || a.busy.fault != b.busy.fault {
        return Err(format!(
            "{what}: busy breakdown diverged: {:?} vs {:?}",
            a.busy, b.busy
        ));
    }
    Ok(())
}

/// Count audit records matching a predicate.
fn count(log: &AuditLog, pred: impl Fn(&AuditEvent) -> bool) -> u64 {
    log.records().iter().filter(|r| pred(&r.event)).count() as u64
}

/// Audit-vs-counter coherence: the log carries exactly one record per
/// counted event, for every counter that has a record type.
fn assert_audit_coherent(out: &SimOutcome, what: &str) -> Result<(), String> {
    let f = out
        .fault
        .as_ref()
        .ok_or_else(|| format!("{what}: fault outcome missing"))?;
    let s = &f.stats;
    let checks: [(&str, u64, u64); 7] = [
        (
            "node_failed",
            count(&f.audit, |e| matches!(e, AuditEvent::NodeFailed { .. })),
            s.node_failures,
        ),
        (
            "node_recovered",
            count(&f.audit, |e| matches!(e, AuditEvent::NodeRecovered { .. })),
            s.node_recoveries,
        ),
        (
            "node_drained",
            count(&f.audit, |e| matches!(e, AuditEvent::NodeDrained { .. })),
            s.drains,
        ),
        (
            "reclaim_wave",
            count(&f.audit, |e| matches!(e, AuditEvent::ReclaimWave { .. })),
            s.reclaim_waves,
        ),
        (
            "task_killed",
            count(&f.audit, |e| matches!(e, AuditEvent::TaskKilled { .. })),
            s.tasks_killed,
        ),
        (
            "task_requeued",
            count(&f.audit, |e| matches!(e, AuditEvent::TaskRequeued { .. })),
            s.tasks_requeued,
        ),
        (
            "task_lost",
            count(&f.audit, |e| matches!(e, AuditEvent::TaskLost { .. })),
            s.tasks_lost,
        ),
    ];
    for (name, in_log, in_stats) in checks {
        if in_log != in_stats {
            return Err(format!(
                "{what}: audit/{name} has {in_log} records but counter says {in_stats}"
            ));
        }
    }
    // Kill conservation: every kill resolves to a requeue or a loss by
    // the time the queue drains.
    if s.tasks_killed != s.tasks_requeued + s.tasks_lost {
        return Err(format!(
            "{what}: kill conservation broken: {} killed != {} requeued + {} lost",
            s.tasks_killed, s.tasks_requeued, s.tasks_lost
        ));
    }
    // A lease can only be evicted because its node left service.
    let evicted = count(&f.audit, |e| matches!(e, AuditEvent::PoolEvicted { .. }));
    if evicted > s.node_failures {
        return Err(format!(
            "{what}: {evicted} pool evictions exceed {} node failures",
            s.node_failures
        ));
    }
    // Seq is the application order: strictly increasing from 0, times
    // non-decreasing.
    for (i, r) in f.audit.records().iter().enumerate() {
        if r.seq != i as u64 {
            return Err(format!("{what}: audit seq {} at index {i}", r.seq));
        }
    }
    for w in f.audit.records().windows(2) {
        if w[0].time > w[1].time {
            return Err(format!(
                "{what}: audit times regress: {} then {}",
                w[0].time, w[1].time
            ));
        }
    }
    Ok(())
}

/// Property 1: `with_faults(FaultConfig::disabled())` is bit-for-bit
/// the historical fault-free path — identical records, event stream,
/// and busy breakdown; no fault outcome, no fault busy time.
#[test]
fn fault_off_is_bit_for_bit() {
    forall("fault-off equivalence", 8, |g| {
        let nodes = 2 + g.usize(0, 6) as u32;
        let seed = g.int(0, u64::MAX - 1);
        let subs = fuzzed_subs(g, nodes);
        let plain = run_sim(quiet_sim(nodes, seed), &subs);
        let off = run_sim(
            quiet_sim(nodes, seed).with_faults(FaultConfig::disabled()),
            &subs,
        );
        assert_same_schedule(&plain, &off, "fault-off")?;
        if off.fault.is_some() {
            return Err("disabled faults still produced a fault outcome".into());
        }
        if off.busy.fault != 0.0 || plain.busy.fault != 0.0 {
            return Err("fault busy time accrued with faults off".into());
        }
        Ok(())
    });
}

/// Property 2: deterministic-recovery churn (one reclamation wave,
/// optionally one later maintenance window, holds well inside the
/// horizon, never more than half the machine down) conserves every
/// task: all records end `Done`, nothing is lost (at most one kill per
/// task, under the retry budget), the audit log matches the counters,
/// and a re-run reproduces the audit log byte for byte. Pool on and
/// off both hold.
#[test]
fn deterministic_churn_conserves_tasks() {
    forall("churn conservation", 10, |g| {
        let nodes = 4 + g.usize(0, 6) as u32;
        let seed = g.int(0, u64::MAX - 1);
        let subs = fuzzed_subs(g, nodes);
        // One wave at 20–50 s, recovered by 110 s; an optional drain
        // window at 120–160 s, recovered by 240 s. Windows never
        // overlap, so each takes at most half the (otherwise fully up)
        // machine and every Recover lands far inside the horizon.
        let with_drain = g.usize(0, 2) > 0;
        let fault = FaultConfig {
            reclaim_times: vec![g.f64(20.0, 50.0)],
            reclaim_count: 1 + g.usize(0, (nodes as usize / 2).saturating_sub(1)),
            reclaim_hold: g.f64(30.0, 60.0),
            drain_times: if with_drain { vec![g.f64(120.0, 160.0)] } else { Vec::new() },
            drain_count: if with_drain { 1 + g.usize(0, nodes as usize / 2 - 1) } else { 0 },
            drain_hold: g.f64(40.0, 80.0),
            horizon: 100_000.0,
            retry: RetryPolicy::default(),
            ..FaultConfig::disabled()
        };
        fault.validate().map_err(|e| format!("config invalid: {e}"))?;
        let pooled = g.usize(0, 2) > 0;
        let build = || {
            let sim = quiet_sim(nodes, seed).with_faults(fault.clone());
            if pooled {
                let n = nodes as usize;
                sim.with_pool(PoolConfig {
                    size: (n / 4).max(1),
                    min: (n / 8).min((n / 4).max(1)),
                    max: (3 * n / 4).max((n / 4).max(1)),
                    ..PoolConfig::disabled()
                })
            } else {
                sim
            }
        };
        let out = run_sim(build(), &subs);
        assert_audit_coherent(&out, "churn")?;
        let f = out.fault.as_ref().expect("coherence checked fault presence");
        // Exactly one wave fired; the drain window drained its full
        // member list (all members were up when it opened).
        if f.stats.reclaim_waves != 1 {
            return Err(format!("expected 1 reclaim wave, saw {}", f.stats.reclaim_waves));
        }
        if with_drain && f.stats.drains != fault.drain_count as u64 {
            return Err(format!(
                "expected {} drains, saw {}",
                fault.drain_count, f.stats.drains
            ));
        }
        // Every node that went down came back (all holds are inside
        // the horizon, and drained nodes recover too).
        if f.stats.node_recoveries != f.stats.node_failures + f.stats.drains {
            return Err(format!(
                "{} recoveries != {} failures + {} drains",
                f.stats.node_recoveries, f.stats.node_failures, f.stats.drains
            ));
        }
        // At most one kill per task (a single wave), so the default
        // 3-retry budget can never exhaust: nothing may be lost, and
        // with capacity always ≥ half the machine every task finishes.
        if f.stats.tasks_lost != 0 {
            return Err(format!("{} tasks lost under a single wave", f.stats.tasks_lost));
        }
        for r in &out.records {
            if r.state != TaskState::Done {
                return Err(format!("task {} ended {:?}, not Done", r.task, r.state));
            }
        }
        if out.hold_invariant_violated {
            return Err("hold invariant violated".into());
        }
        if let Some(p) = &out.pool {
            if p.invariant_violated {
                return Err("pool lease-conservation invariant violated".into());
            }
        }
        if f.audit.is_empty() || out.busy.fault <= 0.0 {
            return Err("churn ran but left no audit records / busy time".into());
        }
        // Replay: the same build on the same submissions reproduces
        // the audit log byte for byte and the schedule exactly.
        let again = run_sim(build(), &subs);
        assert_same_schedule(&out, &again, "churn replay")?;
        let g2 = again.fault.as_ref().expect("replay fault outcome");
        if let Some(diff) = AuditLog::replay_diff(&f.audit, &g2.audit) {
            return Err(format!("audit replay diverged: {diff}"));
        }
        if f.audit.to_text() != g2.audit.to_text() {
            return Err("audit text not byte-identical across replays".into());
        }
        Ok(())
    });
}

/// Property 3: MTBF churn keeps the structural invariants even when
/// recovery is *not* guaranteed (Recover draws at or past the horizon
/// are dropped, so capacity loss can be permanent): kill conservation
/// and audit coherence still hold, no task record is left mid-flight
/// (everything ends `Done` or `Pending`), and lost tasks are exactly
/// the `task_lost` audit records.
#[test]
fn mtbf_churn_keeps_structural_invariants() {
    forall("mtbf churn", 6, |g| {
        let nodes = 4 + g.usize(0, 6) as u32;
        let seed = g.int(0, u64::MAX - 1);
        let subs = fuzzed_subs(g, nodes);
        let fault = FaultConfig {
            // Aggressive: each node fails roughly once per 60–200 s.
            mtbf: g.f64(60.0, 200.0),
            mttr: g.f64(5.0, 40.0),
            horizon: 300.0,
            retry: RetryPolicy { max_retries: 2, backoff: 0.5 },
            ..FaultConfig::disabled()
        };
        let out = run_sim(quiet_sim(nodes, seed).with_faults(fault), &subs);
        assert_audit_coherent(&out, "mtbf")?;
        for r in &out.records {
            if r.state != TaskState::Done && r.state != TaskState::Pending {
                return Err(format!(
                    "task {} left mid-flight in state {:?}",
                    r.task, r.state
                ));
            }
        }
        if out.hold_invariant_violated {
            return Err("hold invariant violated".into());
        }
        Ok(())
    });
}

/// Property 4: every churn preset, run through the contention entry
/// point with the pool fleet enabled (the `churn` CLI configuration),
/// replays to a byte-identical audit log and an identical summary.
/// The deterministic presets additionally pin their structural event
/// counts and full-drain guarantees.
#[test]
fn replay_determinism_on_churn_presets() {
    let nodes = 16u32;
    let seed = 7u64;
    for preset in CHURN_PRESETS {
        let scenario = ChurnScenario::preset(preset, nodes).expect(preset);
        let n = nodes as usize;
        let opts = ContentionOpts {
            pool: PoolConfig {
                size: (n / 4).max(1),
                min: (n / 8).min((n / 4).max(1)),
                max: (3 * n / 4).max((n / 4).max(1)),
                ..PoolConfig::disabled()
            },
            fault: scenario.fault.clone(),
            ..ContentionOpts::classic(true, seed)
        };
        let a = run_contention_with(&scenario.mix, opts.clone()).expect(preset);
        let b = run_contention_with(&scenario.mix, opts).expect(preset);
        let fa = a.fault.as_ref().unwrap_or_else(|| panic!("{preset}: no fault outcome"));
        let fb = b.fault.as_ref().unwrap_or_else(|| panic!("{preset}: no fault outcome"));
        if let Some(diff) = AuditLog::replay_diff(&fa.audit, &fb.audit) {
            panic!("{preset}: audit replay diverged: {diff}");
        }
        assert_eq!(
            fa.audit.to_text(),
            fb.audit.to_text(),
            "{preset}: audit text not byte-identical"
        );
        assert_eq!(fa.stats, fb.stats, "{preset}: fault counters diverged");
        assert_eq!(a.span, b.span, "{preset}: span diverged");
        assert_eq!(a.backfills, b.backfills, "{preset}: backfills diverged");
        assert_eq!(a.unfinished, b.unfinished, "{preset}: unfinished diverged");
        for (x, y) in a.reports.iter().zip(&b.reports) {
            assert_eq!(
                x.median_launch_latency, y.median_launch_latency,
                "{preset}: median latency diverged"
            );
            assert_eq!(x.completed, y.completed, "{preset}: completions diverged");
        }
        match preset {
            // Two waves of nodes/8 = 2 distinct nodes each, recovering
            // at 150 s and 290 s — inside the 400 s horizon, and before
            // the next wave, so the counts are exact. A task can be
            // killed at most twice (once per wave) against a 4-retry
            // budget, so nothing is lost and everything drains.
            "churn_reclaim" => {
                assert_eq!(fa.stats.reclaim_waves, 2, "{preset}: wave count");
                assert_eq!(fa.stats.node_failures, 4, "{preset}: failures");
                assert_eq!(fa.stats.node_recoveries, 4, "{preset}: recoveries");
                assert_eq!(fa.stats.tasks_lost, 0, "{preset}: losses");
                assert_eq!(a.unfinished, 0, "{preset}: unfinished tasks");
                assert!(!fa.audit.is_empty(), "{preset}: empty audit log");
            }
            // Drains are graceful: two windows of nodes/8 = 2 nodes,
            // recovering at 220 s and 420 s inside the 600 s horizon.
            // Nothing is ever killed.
            "churn_drain" => {
                assert_eq!(fa.stats.drains, 4, "{preset}: drain count");
                assert_eq!(fa.stats.node_recoveries, 4, "{preset}: recoveries");
                assert_eq!(fa.stats.tasks_killed, 0, "{preset}: graceful drains kill");
                assert_eq!(fa.stats.tasks_lost, 0, "{preset}: losses");
                assert_eq!(a.unfinished, 0, "{preset}: unfinished tasks");
                assert!(!fa.audit.is_empty(), "{preset}: empty audit log");
            }
            // churn_full always fires its wave (150 s < 400 s horizon).
            "churn_full" => {
                assert!(fa.stats.reclaim_waves >= 1, "{preset}: wave missing");
                assert!(!fa.audit.is_empty(), "{preset}: empty audit log");
            }
            // churn_mtbf is probabilistic — a seed may draw no failure
            // inside the 150 s horizon, so only coherence is pinned.
            _ => {}
        }
        // Counter/audit coherence holds on every preset.
        let kills = count(&fa.audit, |e| matches!(e, AuditEvent::TaskKilled { .. }));
        assert_eq!(kills, fa.stats.tasks_killed, "{preset}: kill records");
        assert_eq!(
            fa.stats.tasks_killed,
            fa.stats.tasks_requeued + fa.stats.tasks_lost,
            "{preset}: kill conservation"
        );
    }
}

/// Property 5: a reclamation wave through the pooled configuration
/// evicts dead leases (audited as `pool_evicted`) without ever
/// breaking lease conservation, and the fleet's invariant flag stays
/// clean across the evict/re-grow cycle. This one runs at the sim
/// level because the lease-conservation flag ([`SimOutcome::pool`]'s
/// `invariant_violated`) is not part of the contention report.
#[test]
fn fleet_survives_reclaim_evictions() {
    let nodes = 16u32;
    let seed = 11u64;
    let scenario = ChurnScenario::preset("churn_reclaim", nodes).expect("preset");
    let n = nodes as usize;
    let mut sim = SchedulerSim::new(
        Cluster::tx_green(nodes),
        CostModel::slurm_like_tx_green(),
        NoiseModel::dedicated(),
        seed,
    )
    .with_backfill(true)
    .with_pool(PoolConfig {
        size: (n / 4).max(1),
        min: (n / 8).min((n / 4).max(1)),
        max: (3 * n / 4).max((n / 4).max(1)),
        ..PoolConfig::disabled()
    })
    .with_faults(scenario.fault.clone());
    let mut q = EventQueue::new();
    for sub in scenario.mix.generate(seed) {
        sim.submit_at(&mut q, sub.at, sub.spec);
    }
    let out = sim.run(&mut q);
    assert_audit_coherent(&out, "fleet churn").unwrap();
    let f = out.fault.as_ref().expect("fault outcome");
    let pool = out.pool.as_ref().expect("pool outcome");
    assert!(!pool.invariant_violated, "lease conservation violated under churn");
    let evicted = count(&f.audit, |e| matches!(e, AuditEvent::PoolEvicted { .. }));
    assert!(
        evicted <= f.stats.node_failures,
        "{evicted} evictions from {} failures",
        f.stats.node_failures
    );
    // The deterministic wave schedule: two waves of nodes/8 = 2
    // distinct nodes, both recovering inside the 400 s horizon.
    assert_eq!(f.stats.reclaim_waves, 2, "wave count");
    assert_eq!(f.stats.node_failures, 4, "failures");
    assert_eq!(f.stats.node_recoveries, 4, "recoveries");
    assert_eq!(f.stats.tasks_lost, 0, "at most 2 kills per task under a 4-retry budget");
    for r in &out.records {
        assert_eq!(
            r.state,
            TaskState::Done,
            "task {} must finish after recoveries",
            r.task
        );
    }
}
