//! Property tests for the placement subsystem.
//!
//! The free-capacity index is only allowed to be *fast*; it is never
//! allowed to disagree with a brute-force scan of the cluster. These
//! tests drive randomized allocate/release/state-change sequences and
//! assert, after every step, that the index's answers match the
//! scan-based searches (`Cluster::find_fit_node`,
//! `Cluster::find_idle_nodes`) and that the internal bucket structure is
//! exactly consistent with the node table. A second suite runs every
//! placement policy end-to-end through the scheduler.

use llsched::cluster::{Cluster, NodeState};
use llsched::placement::{FreeIndex, PlacementEngine, Strategy, ALL_STRATEGIES};
use llsched::scheduler::core::{SchedulerSim, TaskModel};
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::job::{ComputeBatch, JobSpec, ResourceRequest, SchedTaskSpec, TaskState};
use llsched::scheduler::noise::NoiseModel;
use llsched::testing::prop::{forall, Gen};

/// One live allocation in the reference model.
struct Alloc {
    node: u32,
    mask: llsched::cluster::CoreMask,
    mem: u64,
}

#[test]
fn free_index_matches_brute_force_under_random_churn() {
    forall("index == scan under churn", 60, |g| {
        let nodes = g.int(1, 32) as u32 + 1;
        let cores = *g.choose(&[2u32, 4, 8, 64]);
        let mem_per_node = 1024u64;
        let mut cluster = Cluster::homogeneous(nodes, cores, mem_per_node);
        // Sometimes fence off a reservation slice.
        let reservation = if nodes >= 4 && g.chance(0.5) {
            let k = g.int(1, (nodes / 2) as u64) as u32;
            cluster
                .reserve("bench", (0..k).collect())
                .map_err(|e| e.to_string())?;
            Some("bench")
        } else {
            None
        };
        let mut index = FreeIndex::build(&cluster);
        let mut allocs: Vec<Alloc> = Vec::new();

        let steps = 30 + g.usize(0, 50);
        for _ in 0..steps {
            let action = g.int(0, 9);
            match action {
                // Allocate through the index's first-fit answer.
                0..=4 => {
                    let want = g.int(1, cores as u64) as u32;
                    let mem = g.int(0, 64);
                    let res = if g.chance(0.5) { reservation } else { None };
                    let scan = cluster.find_fit_node(want, mem, res);
                    let part = index.partition_for(res);
                    let indexed = part.and_then(|p| index.first_fit(&cluster, p, want, mem));
                    if indexed != scan {
                        return Err(format!(
                            "first_fit {indexed:?} vs scan {scan:?} (want {want} cores, {mem} MiB, res {res:?})"
                        ));
                    }
                    if let Some(node) = indexed {
                        let mask = cluster
                            .allocate_on(node, want, mem)
                            .map_err(|e| format!("index said it fits: {e}"))?;
                        let free = cluster.node(node).unwrap().free_cores();
                        index.on_delta(node, free);
                        allocs.push(Alloc { node, mask, mem });
                    }
                }
                // Release a random live allocation.
                5..=7 => {
                    if allocs.is_empty() {
                        continue;
                    }
                    let i = g.usize(0, allocs.len() - 1);
                    let a = allocs.swap_remove(i);
                    cluster
                        .release_on(a.node, &a.mask, a.mem)
                        .map_err(|e| e.to_string())?;
                    let free = cluster.node(a.node).unwrap().free_cores();
                    index.on_delta(a.node, free);
                }
                // Flip a node's lifecycle state.
                _ => {
                    let id = g.int(0, nodes as u64 - 1) as u32;
                    let state = *g.choose(&[NodeState::Up, NodeState::Draining, NodeState::Down]);
                    cluster.node_mut(id).unwrap().set_state(state);
                    index.on_state_change(id, state);
                }
            }

            // Invariants after every step.
            index.check_consistency(&cluster)?;
            for res in [None, reservation] {
                let Some(part) = index.partition_for(res) else {
                    continue;
                };
                // Idle pool matches the scan.
                let scan_idle = cluster.find_idle_nodes(nodes, res);
                if index.idle_count(&cluster, part) != scan_idle.len() {
                    return Err(format!(
                        "idle_count {} vs scan {} (res {res:?})",
                        index.idle_count(&cluster, part),
                        scan_idle.len()
                    ));
                }
                if index.idle_lowest(&cluster, part) != scan_idle.first().copied() {
                    return Err(format!(
                        "idle_lowest {:?} vs scan {:?}",
                        index.idle_lowest(&cluster, part),
                        scan_idle.first()
                    ));
                }
                // Fit feasibility and extremal-choice properties.
                let want = g.int(1, cores as u64) as u32;
                let scan = cluster.find_fit_node(want, 0, res);
                let best = index.best_fit(&cluster, part, want, 0);
                let worst = index.worst_fit(&cluster, part, want, 0);
                if best.is_some() != scan.is_some() || worst.is_some() != scan.is_some() {
                    return Err(format!(
                        "feasibility disagreement: best {best:?} worst {worst:?} scan {scan:?}"
                    ));
                }
                let eligible_free: Vec<u32> = scan_eligible_free(&cluster, res, want);
                if let Some(b) = best {
                    let f = cluster.node(b).unwrap().free_cores();
                    if eligible_free.iter().any(|&x| x < f) {
                        return Err(format!("best_fit picked {f} free, tighter node exists"));
                    }
                }
                if let Some(w) = worst {
                    let f = cluster.node(w).unwrap().free_cores();
                    if eligible_free.iter().any(|&x| x > f) {
                        return Err(format!("worst_fit picked {f} free, freer node exists"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Free-core counts of all Up nodes eligible for `res` that fit `want`.
fn scan_eligible_free(cluster: &Cluster, res: Option<&str>, want: u32) -> Vec<u32> {
    cluster
        .eligible_nodes(res)
        .into_iter()
        .filter_map(|id| {
            let n = cluster.node(id).unwrap();
            if n.can_fit(want, 0) {
                Some(n.free_cores())
            } else {
                None
            }
        })
        .collect()
}

#[test]
fn engine_placements_keep_index_consistent() {
    forall("engine keeps index consistent", 40, |g| {
        let nodes = g.int(1, 16) as u32 + 1;
        let strategy = *g.choose(&ALL_STRATEGIES);
        let mut cluster = Cluster::homogeneous(nodes, 8, 4096);
        let mut engine = PlacementEngine::new(&cluster, strategy, g.int(0, u64::MAX - 1));
        let mut placements = Vec::new();
        for _ in 0..g.usize(10, 60) {
            if g.chance(0.6) {
                let p = if g.chance(0.3) {
                    engine.place_whole(&mut cluster, None)
                } else {
                    engine.place_cores(&mut cluster, g.int(1, 8) as u32, g.int(0, 128), None)
                };
                if let Some(p) = p {
                    placements.push(p);
                }
            } else if !placements.is_empty() {
                let i = g.usize(0, placements.len() - 1);
                let p = placements.swap_remove(i);
                engine.release(&mut cluster, &p).map_err(|e| e.to_string())?;
            }
            engine
                .index()
                .check_consistency(&cluster)
                .map_err(|e| format!("{strategy}: {e}"))?;
        }
        Ok(())
    });
}

// ---- every policy, end-to-end through the scheduler --------------------

fn mixed_job() -> JobSpec {
    // Whole-node and core-level tasks interleaved, so both placement
    // paths (idle pool + fit buckets) are exercised.
    let mut tasks = Vec::new();
    for i in 0..24usize {
        if i % 3 == 0 {
            tasks.push(SchedTaskSpec {
                request: ResourceRequest::WholeNode,
                duration: 10.0,
                batch: ComputeBatch { count: 64, each: 10.0 / 64.0 },
                lanes: 64,
            });
        } else {
            tasks.push(SchedTaskSpec {
                request: ResourceRequest::Cores { cores: 4, mem_mib: 64 },
                duration: 8.0,
                batch: ComputeBatch { count: 1, each: 8.0 },
                lanes: 4,
            });
        }
    }
    JobSpec {
        name: "mixed".into(),
        tasks,
        reservation: None,
        priority: 0,
        preemptable: false,
    }
}

fn run_with(strategy: Strategy) -> llsched::scheduler::core::SimOutcome {
    let sim = SchedulerSim::new(
        Cluster::tx_green(6),
        CostModel::slurm_like_tx_green(),
        NoiseModel::dedicated(),
        7,
    )
    .with_server_speed(1.0)
    .with_task_model(TaskModel {
        startup: 0.0,
        jitter_sigma: 0.0,
        p_node_late: 0.0,
        late_range: (0.0, 0.0),
    })
    .with_placement(strategy);
    assert_eq!(sim.placement(), strategy);
    let (out, _) = sim.run_single(mixed_job());
    out
}

#[test]
fn first_fit_policy_completes_mixed_workload() {
    let out = run_with(Strategy::FirstFit);
    assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    assert_eq!(out.timeline.last().unwrap().1, 0, "resources return");
}

#[test]
fn best_fit_policy_completes_mixed_workload() {
    let out = run_with(Strategy::BestFit);
    assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    assert_eq!(out.timeline.last().unwrap().1, 0);
}

#[test]
fn spread_policy_completes_mixed_workload() {
    let out = run_with(Strategy::Spread);
    assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    assert_eq!(out.timeline.last().unwrap().1, 0);
}

#[test]
fn random_policy_completes_mixed_workload() {
    let out = run_with(Strategy::Random);
    assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    assert_eq!(out.timeline.last().unwrap().1, 0);
}

#[test]
fn node_based_policy_completes_mixed_workload() {
    let out = run_with(Strategy::NodeBased);
    assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    assert_eq!(out.timeline.last().unwrap().1, 0);
}

#[test]
fn policies_are_selectable_via_config() {
    // The config layer resolves every strategy name down to a working
    // run — the same path `llsched run --placement` takes.
    for s in ALL_STRATEGIES {
        let parsed = Strategy::parse(&s.to_string()).unwrap();
        assert_eq!(parsed, s);
        let cfg = llsched::config::RunConfig {
            nodes: 4,
            placement: Some(s),
            ..Default::default()
        };
        assert_eq!(cfg.placement_strategy(), s);
    }
}

#[test]
fn best_fit_packs_denser_than_spread() {
    // Two 4-core placements on a fresh 2-node cluster: best-fit stacks
    // them on one node, spread puts them on different nodes. The
    // policies are observably different, not just differently named.
    for (strategy, same_node) in [(Strategy::BestFit, true), (Strategy::Spread, false)] {
        let mut cluster = Cluster::tx_green(2);
        let mut engine = PlacementEngine::new(&cluster, strategy, 1);
        let a = engine.place_cores(&mut cluster, 4, 0, None).unwrap();
        let b = engine.place_cores(&mut cluster, 4, 0, None).unwrap();
        assert_eq!(a.node == b.node, same_node, "{strategy}");
    }
}
