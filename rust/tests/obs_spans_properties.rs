//! Wait-attribution property suite.
//!
//! [`reconstruct_spans`] promises, in order:
//!
//! 1. **Blame tiles the wait.** On a drop-free snapshot, every
//!    launched job's per-cause blame sums to its attributed wait (to
//!    float rounding) — across pool on/off, churn on/off, and through
//!    the federated gateway with steal hops.
//! 2. **Drops demote, never lie.** When the ring dropped records the
//!    span set and every span are flagged partial; the sum invariant
//!    is no longer claimed.
//! 3. **Attribution is an observer.** The blame switch changes no
//!    schedule byte — recorder-off runs stay bit-for-bit identical
//!    with blame on or off, and a recorder-off run never grows a
//!    rollup.

use llsched::coordinator::experiment::{
    run_contention_federated, run_contention_with, ContentionOpts, ContentionResult,
};
use llsched::fault::scenario::ChurnScenario;
use llsched::fault::FaultConfig;
use llsched::federation::FederationConfig;
use llsched::obs::{reconstruct_spans, SpanSet, BLAME_CAUSES};
use llsched::pool::PoolConfig;
use llsched::workload::contention::ContentionMix;

/// Relative-with-floor closeness for telescoped float sums.
fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-6 * a.abs().max(b.abs()).max(1.0)
}

/// The `trace`/`explain` commands' cluster-scaled elastic pool.
fn pooled(nodes: u32) -> PoolConfig {
    let n = nodes.max(2) as usize;
    PoolConfig {
        size: (n / 4).max(1),
        min: (n / 8).min((n / 4).max(1)),
        max: (3 * n / 4).max((n / 4).max(1)),
        ..PoolConfig::disabled()
    }
}

/// Workload + fault plan for a preset name (churn presets carry their
/// scenario's fault plan; contention presets run fault-free).
fn case(preset: &str, nodes: u32) -> (ContentionMix, FaultConfig) {
    if preset.starts_with("churn_") {
        let sc = ChurnScenario::preset(preset, nodes).unwrap();
        (sc.mix, sc.fault)
    } else {
        (
            ContentionMix::preset(preset, nodes).unwrap(),
            FaultConfig::disabled(),
        )
    }
}

/// Property 1 on one drop-free span set: non-negative parts, and the
/// blame decomposition tiles every launched span's wait exactly.
fn assert_blame_tiles(set: &SpanSet, label: &str) {
    assert!(!set.partial, "{label}: a drop-free snapshot yields a complete set");
    let launched = set.spans.iter().filter(|s| s.launched).count();
    assert!(launched > 0, "{label}: the run launches jobs");
    for s in set.spans.iter().filter(|s| s.launched) {
        assert!(!s.partial, "{label}: job {} partial without drops", s.job);
        assert!(s.wait_s >= 0.0, "{label}: job {} wait is negative", s.job);
        for (i, name) in BLAME_CAUSES.iter().enumerate() {
            assert!(s.blame.get(i) >= 0.0, "{label}: job {} {name} negative", s.job);
        }
        assert!(
            close(s.blame.total(), s.wait_s),
            "{label}: job {} blame {} != wait {}",
            s.job,
            s.blame.total(),
            s.wait_s
        );
    }
}

/// Property 1 over the pool × churn grid: whatever combination of
/// elastic pool and fault churn produced the wait, the decomposition
/// tiles it — and the attached per-class rollup agrees with an
/// independent reconstruction.
#[test]
fn blame_tiles_the_wait_across_pool_and_churn_grid() {
    let grid = [
        ("burst", false, 3u64),
        ("burst", true, 7),
        ("churn_mtbf", true, 11),
        ("churn_full", false, 5),
    ];
    for (preset, pool_on, seed) in grid {
        let nodes = 32u32;
        let (mix, fault) = case(preset, nodes);
        let opts = ContentionOpts {
            pool: if pool_on { pooled(nodes) } else { PoolConfig::disabled() },
            fault,
            trace_cap: 1 << 20,
            blame: true,
            ..ContentionOpts::classic(true, seed)
        };
        let res = run_contention_with(&mix, opts).unwrap();
        let snap = res.obs.as_ref().expect("traced run carries a snapshot");
        assert_eq!(snap.dropped, 0, "{preset}: a 1<<20 ring is drop-free here");
        let set = reconstruct_spans(snap);
        let label = format!("{preset} pool={pool_on}");
        assert_blame_tiles(&set, &label);
        let rollup = res.blame.as_ref().expect("the blame switch attaches a rollup");
        let jobs: usize = rollup.iter().map(|cb| cb.jobs).sum();
        assert_eq!(
            jobs,
            set.spans.iter().filter(|s| s.launched).count(),
            "{label}: the rollup covers every launched span"
        );
        // Unlaunched spans carry zero blame, so the per-class totals
        // must reproduce the set-wide aggregate cause by cause.
        let total = set.total_blame();
        for (i, name) in BLAME_CAUSES.iter().enumerate() {
            let sum: f64 = rollup.iter().map(|cb| cb.blame.get(i)).sum();
            assert!(close(sum, total.get(i)), "{label}: rollup {name} diverged");
        }
    }
}

/// Property 1 through the federated gateway: spans keyed by gateway
/// job index survive batching and steal hops in the merged snapshot,
/// and the gateway/steal segments telescope with the local window.
#[test]
fn blame_tiles_the_wait_through_the_federated_gateway() {
    let mix = ContentionMix::preset("burst", 64).unwrap();
    let fed = FederationConfig {
        instances: 2,
        ..FederationConfig::default()
    };
    let opts = ContentionOpts {
        pool: pooled(32),
        trace_cap: 1 << 20,
        blame: true,
        ..ContentionOpts::classic(true, 9)
    };
    let res = run_contention_federated(&mix, opts, fed).unwrap();
    let snap = res.obs.as_ref().expect("traced federated run carries a snapshot");
    assert_eq!(snap.dropped, 0, "a 1<<20 ring is drop-free here");
    let set = reconstruct_spans(snap);
    assert_blame_tiles(&set, "federated burst");
    for s in set.spans.iter().filter(|s| s.launched) {
        assert_ne!(s.pid, u32::MAX, "a launched span has a real owning instance");
    }
    let fedsum = res.federation.as_ref().expect("federated run carries the rollup");
    assert!(fedsum.batches > 0, "the gateway flushed batches");
    // The gateway traces `StealAttempt` (keyed by gateway job index)
    // exactly where it counts a steal, so recorded steals must
    // surface as span hops.
    if fedsum.steals > 0 {
        assert!(
            set.spans.iter().any(|s| s.steal_hops > 0),
            "recorded steals surface as span hops"
        );
    }
    assert!(res.blame.is_some(), "the blame switch works through the gateway");
}

/// Property 2: a ring too small for the run drops records, which must
/// demote the whole set — and every span in it — to partial.
#[test]
fn tiny_ring_drops_mark_spans_partial() {
    let mix = ContentionMix::preset("burst", 32).unwrap();
    let opts = ContentionOpts {
        pool: pooled(32),
        trace_cap: 64,
        blame: true,
        ..ContentionOpts::classic(true, 3)
    };
    let res = run_contention_with(&mix, opts).unwrap();
    let snap = res.obs.as_ref().expect("traced run carries a snapshot");
    assert!(snap.dropped > 0, "a burst run overflows a 64-slot ring");
    let set = reconstruct_spans(snap);
    assert!(set.partial, "drops demote the set");
    assert!(set.spans.iter().all(|s| s.partial), "drops demote every span");
}

/// Property 3: the blame switch observes, it never steers — and with
/// the recorder off it is inert (no snapshot, no rollup, identical
/// schedule bytes).
#[test]
fn blame_switch_never_changes_the_schedule() {
    let (mix, fault) = case("churn_full", 32);
    let opts = |cap: usize, blame: bool| ContentionOpts {
        pool: pooled(32),
        fault: fault.clone(),
        trace_cap: cap,
        blame,
        ..ContentionOpts::classic(true, 11)
    };
    // Recorder off: blame on/off must be bit-for-bit identical and
    // neither run grows a snapshot or rollup.
    let off_plain = run_contention_with(&mix, opts(0, false)).unwrap();
    let off_blamed = run_contention_with(&mix, opts(0, true)).unwrap();
    assert!(off_plain.obs.is_none() && off_blamed.obs.is_none());
    assert!(off_plain.blame.is_none(), "no recorder, no rollup");
    assert!(off_blamed.blame.is_none(), "blame needs the recorder");
    assert_schedules_match(&off_plain, &off_blamed, "recorder off");
    // Recorder on: blame attaches the rollup without moving a byte.
    let on_plain = run_contention_with(&mix, opts(1 << 18, false)).unwrap();
    let on_blamed = run_contention_with(&mix, opts(1 << 18, true)).unwrap();
    assert!(on_plain.blame.is_none(), "blame stays opt-in");
    assert!(on_blamed.blame.is_some(), "recorder + switch = rollup");
    assert_schedules_match(&off_plain, &on_blamed, "recorder on");
}

/// Bit-for-bit schedule equality across the counters and per-class
/// float quantiles two runs of the same seed must share.
fn assert_schedules_match(a: &ContentionResult, b: &ContentionResult, label: &str) {
    assert_eq!(a.span.to_bits(), b.span.to_bits(), "{label}: span diverged");
    assert_eq!(a.backfills, b.backfills, "{label}: backfills diverged");
    assert_eq!(a.unfinished, b.unfinished, "{label}: unfinished diverged");
    assert_eq!(
        a.overdue_preemptions, b.overdue_preemptions,
        "{label}: preemptions diverged"
    );
    for (ra, rb) in a.reports.iter().zip(&b.reports) {
        assert_eq!(
            ra.median_launch_latency.to_bits(),
            rb.median_launch_latency.to_bits(),
            "{label}: median latency diverged"
        );
        assert_eq!(
            ra.p95_launch_latency.to_bits(),
            rb.p95_launch_latency.to_bits(),
            "{label}: p95 latency diverged"
        );
        assert_eq!(ra.completed, rb.completed, "{label}: completions diverged");
    }
}
