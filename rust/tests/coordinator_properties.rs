//! Property-based tests on the coordinator invariants, using the crate's
//! mini property-testing toolkit (`llsched::testing::prop`).
//!
//! Invariants covered:
//!  * aggregation conserves compute tasks and work, for every mode and
//!    random workload/cluster shape;
//!  * node scripts partition the task index space exactly;
//!  * the scheduler always drains: every submitted task reaches DONE with
//!    monotone timestamps, resources return to idle, and the utilization
//!    timeline never exceeds the machine;
//!  * batching/routing: node-based dispatch count == node count,
//!    multi-level == processor count;
//!  * priority ordering and preemption state invariants.

use llsched::aggregation::plan::{Aggregator, ClusterShape, Workload};
use llsched::aggregation::script::build_scripts;
use llsched::aggregation::{for_mode, MultiLevel, NodeBased};
use llsched::cluster::Cluster;
use llsched::config::Mode;
use llsched::scheduler::core::{SchedulerSim, TaskModel};
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::job::TaskState;
use llsched::scheduler::noise::NoiseModel;
use llsched::testing::prop::{forall, Gen};

fn gen_workload(g: &mut Gen) -> Workload {
    if g.chance(0.5) {
        Workload::Uniform {
            count: g.int(1, 2000),
            duration: g.f64(0.1, 100.0),
        }
    } else {
        let n = g.usize(1, 300);
        Workload::Explicit(g.vec(n, |g| g.f64(0.1, 50.0)))
    }
}

fn gen_shape(g: &mut Gen) -> ClusterShape {
    ClusterShape {
        nodes: g.int(1, 64) as u32,
        cores_per_node: *g.choose(&[2u32, 4, 16, 64]),
        task_mem_mib: g.int(0, 1024),
    }
}

#[test]
fn aggregation_conserves_tasks_and_work() {
    forall("aggregation conserves tasks/work", 150, |g| {
        let w = gen_workload(g);
        let shape = gen_shape(g);
        let mode = *g.choose(&[Mode::PerTask, Mode::MultiLevel, Mode::NodeBased]);
        let job = for_mode(mode)
            .plan("prop", &w, &shape)
            .map_err(|e| e.to_string())?;
        // Task conservation (node-based counts via scripts, which are the
        // execution ground truth).
        let total = match mode {
            Mode::NodeBased => build_scripts(w.count(), shape.nodes, shape.cores_per_node, 1)
                .iter()
                .map(|s| s.total_tasks())
                .sum::<u64>(),
            _ => job.total_compute_tasks(),
        };
        if total != w.count() {
            return Err(format!("{mode}: {total} tasks vs workload {}", w.count()));
        }
        // Work conservation for per-core modes (node-based durations are
        // max-lane, checked separately).
        if mode != Mode::NodeBased {
            let planned: f64 = job.tasks.iter().map(|t| t.duration).sum();
            if (planned - w.total_work()).abs() > 1e-6 * w.total_work().max(1.0) {
                return Err(format!("work {planned} vs {}", w.total_work()));
            }
        }
        // Scheduling-task counts: the paper's central quantity.
        let expect = match mode {
            Mode::PerTask => w.count(),
            Mode::MultiLevel => w.count().min(shape.processors()),
            Mode::NodeBased => w.count().min(shape.nodes as u64),
        };
        if job.array_size() != expect {
            return Err(format!(
                "{mode}: array {} vs expected {expect}",
                job.array_size()
            ));
        }
        Ok(())
    });
}

#[test]
fn node_based_duration_is_max_lane() {
    forall("node-based duration = max lane", 100, |g| {
        let n = g.usize(1, 200);
        let durs: Vec<f64> = g.vec(n, |g| g.f64(0.1, 20.0));
        let shape = ClusterShape {
            nodes: g.int(1, 8) as u32,
            cores_per_node: *g.choose(&[2u32, 4, 8]),
            task_mem_mib: 0,
        };
        let w = Workload::Explicit(durs.clone());
        let job = NodeBased::default()
            .plan("p", &w, &shape)
            .map_err(|e| e.to_string())?;
        let scripts = build_scripts(n as u64, shape.nodes, shape.cores_per_node, 1);
        for (task, script) in job.tasks.iter().zip(scripts.iter()) {
            let max_lane: f64 = script
                .lanes
                .iter()
                .map(|l| durs[l.start as usize..l.end as usize].iter().sum::<f64>())
                .fold(0.0, f64::max);
            if (task.duration - max_lane).abs() > 1e-9 {
                return Err(format!("duration {} vs max lane {max_lane}", task.duration));
            }
        }
        Ok(())
    });
}

#[test]
fn scripts_partition_task_space() {
    forall("scripts partition tasks", 150, |g| {
        let total = g.int(0, 5000);
        let nodes = g.int(1, 64) as u32;
        let cores = *g.choose(&[1u32, 2, 16, 64]);
        let scripts = build_scripts(total, nodes, cores, 1);
        let mut covered = 0u64;
        let mut next_expected = 0u64;
        for s in &scripts {
            for l in &s.lanes {
                if l.start != next_expected {
                    return Err(format!("gap at {}", l.start));
                }
                next_expected = l.end;
                covered += l.end - l.start;
            }
        }
        if covered != total {
            return Err(format!("covered {covered} of {total}"));
        }
        Ok(())
    });
}

#[test]
fn scheduler_always_drains_with_clean_state() {
    forall("scheduler drains", 60, |g| {
        let nodes = g.int(1, 8) as u32;
        let cores = *g.choose(&[2u32, 4, 8]);
        let shape = ClusterShape {
            nodes,
            cores_per_node: cores,
            task_mem_mib: 4,
        };
        let count = g.int(1, 200);
        let w = Workload::Uniform {
            count,
            duration: g.f64(0.5, 30.0),
        };
        let mode = *g.choose(&[Mode::PerTask, Mode::MultiLevel, Mode::NodeBased]);
        let job = for_mode(mode)
            .plan("p", &w, &shape)
            .map_err(|e| e.to_string())?;
        let sim = SchedulerSim::new(
            Cluster::homogeneous(nodes, cores, 192 * 1024),
            CostModel::slurm_like_tx_green(),
            NoiseModel::dedicated(),
            g.int(0, u64::MAX - 1),
        )
        .with_server_speed(1.0);
        let (out, _job_id) = sim.run_single(job);
        // Every task DONE with monotone stamps.
        for r in &out.records {
            if r.state != TaskState::Done {
                return Err(format!("task {} in state {:?}", r.task, r.state));
            }
            let (s, e, c) = (
                r.start_t.ok_or("no start")?,
                r.end_t.ok_or("no end")?,
                r.cleanup_t.ok_or("no cleanup")?,
            );
            if !(r.submit_t <= s && s < e && e <= c) {
                return Err(format!("stamps not monotone: {} {s} {e} {c}", r.submit_t));
            }
        }
        // Utilization never exceeds the machine and ends at zero.
        let total_cores = nodes as u64 * cores as u64;
        for &(_, busy) in &out.timeline {
            if busy > total_cores {
                return Err(format!("busy {busy} > machine {total_cores}"));
            }
        }
        if out.timeline.last().map(|x| x.1) != Some(0) {
            return Err("machine not idle at end".into());
        }
        Ok(())
    });
}

#[test]
fn dispatch_counts_match_mode() {
    forall("dispatch count = array size", 40, |g| {
        let nodes = g.int(1, 6) as u32 + 1;
        let cores = 4u32;
        let shape = ClusterShape { nodes, cores_per_node: cores, task_mem_mib: 0 };
        let w = Workload::Uniform {
            count: (nodes as u64) * (cores as u64) * g.int(1, 5),
            duration: 2.0,
        };
        for mode in [Mode::MultiLevel, Mode::NodeBased] {
            let job = for_mode(mode)
                .plan("p", &w, &shape)
                .map_err(|e| e.to_string())?;
            let expect = match mode {
                Mode::MultiLevel => shape.processors(),
                Mode::NodeBased => nodes as u64,
                Mode::PerTask => unreachable!(),
            };
            if job.array_size() != expect {
                return Err(format!("{mode}: {} vs {expect}", job.array_size()));
            }
            let sim = SchedulerSim::new(
                Cluster::homogeneous(nodes, cores, 1024),
                CostModel::slurm_like_tx_green(),
                NoiseModel::dedicated(),
                g.int(0, 1 << 40),
            )
            .with_server_speed(1.0);
            let (out, _) = sim.run_single(job);
            let dispatched = out.records.iter().filter(|r| r.start_t.is_some()).count() as u64;
            if dispatched != expect {
                return Err(format!("{mode}: dispatched {dispatched} vs {expect}"));
            }
        }
        Ok(())
    });
}

#[test]
fn multilevel_oversubscribed_tasks_queue_fairly() {
    forall("oversubscription waves", 40, |g| {
        // More scheduling tasks than cores: every core eventually gets
        // work and runtime covers at least ceil(tasks/cores) waves.
        let cores = 4u32;
        let waves = g.int(2, 5);
        let dur = g.f64(1.0, 10.0);
        let w = Workload::Uniform { count: 4 * waves, duration: dur };
        let shape = ClusterShape { nodes: 1, cores_per_node: cores, task_mem_mib: 0 };
        let job = MultiLevel.plan("p", &w, &shape).map_err(|e| e.to_string())?;
        let sim = SchedulerSim::new(
            Cluster::homogeneous(1, cores, 1024),
            CostModel::ideal(),
            NoiseModel::dedicated(),
            1,
        )
        .with_server_speed(1.0)
        .with_task_model(TaskModel {
            startup: 0.0,
            jitter_sigma: 0.0,
            p_node_late: 0.0,
            late_range: (0.0, 0.0),
        });
        let (out, job_id) = sim.run_single(job);
        let stats = out.job_stats(job_id, dur).ok_or("no stats")?;
        // Array of 4 tasks (one per core), each runs `waves × dur`.
        let expect = waves as f64 * dur;
        if (stats.runtime - expect).abs() > 1e-6 {
            return Err(format!("runtime {} vs {expect}", stats.runtime));
        }
        Ok(())
    });
}
