//! Spot-job preemption demo (paper §I): node-based allocation of
//! preemptable spot jobs releases resources much faster when an
//! interactive job needs the machine.
//!
//! The scenario: a spot job soaks N nodes; at t=120 s an interactive user
//! asks for the machine and the spot job is preempted. We measure the
//! release latency (preemption request → all resources free) for
//! core-based vs node-based spot allocation across scales.
//!
//! ```bash
//! cargo run --release --example spot_preemption
//! ```

use llsched::config::Mode;
use llsched::spot::measure_release;
use llsched::util::fmt::{count, dur, Table};

fn main() -> llsched::Result<()> {
    println!("spot-job release latency after preemption (dedicated system)\n");
    let mut table = Table::new(vec![
        "nodes",
        "core-based tasks",
        "core-based release",
        "node-based tasks",
        "node-based release",
        "speedup",
    ]);
    for nodes in [8u32, 32, 128, 512] {
        let core = measure_release(Mode::MultiLevel, nodes, 64, 120.0, 11)?;
        let node = measure_release(Mode::NodeBased, nodes, 64, 120.0, 11)?;
        table.row(vec![
            nodes.to_string(),
            count(core.sched_tasks),
            dur(core.release_latency),
            count(node.sched_tasks),
            dur(node.release_latency),
            format!("{:.0}x", core.release_latency / node.release_latency.max(1e-9)),
        ]);
    }
    println!("{}", table.render());
    println!("node-based spot jobs need 64x fewer preemption signals and cleanup");
    println!("transactions, so the interactive job that triggered the preemption");
    println!("gets its resources in seconds instead of minutes (paper §I).");
    Ok(())
}
