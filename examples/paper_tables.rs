//! End-to-end driver: regenerate every table and figure of the paper on
//! the simulated TX-Green substrate, write CSV/JSON to `results/`, and
//! print the paper-vs-measured comparison recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example paper_tables            # full matrix
//! cargo run --release --example paper_tables -- --quick # ≤128 nodes
//! ```

use llsched::coordinator::experiment::{fig2_label, median_runs, run_matrix, ExperimentOpts};
use llsched::config::Mode;
use llsched::metrics::overhead::speedup;
use llsched::metrics::report;
use llsched::util::fmt::dur;
use std::path::Path;

/// Paper Table III medians (seconds) for the structural comparison.
const PAPER_MEDIANS: &[(u32, f64, &str, f64)] = &[
    (32, 1.0, "M", 291.0),
    (32, 1.0, "N", 242.0),
    (64, 1.0, "M", 291.0),
    (64, 1.0, "N", 242.0),
    (128, 1.0, "M", 424.0),
    (128, 1.0, "N", 245.0),
    (256, 1.0, "M", 430.0),
    (256, 1.0, "N", 256.0),
    (512, 60.0, "M", 2768.0),
    (512, 60.0, "N", 312.0),
];

fn main() -> llsched::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = ExperimentOpts {
        include_na: false,
        max_nodes: if quick { 128 } else { 512 },
        runs: 3,
        dt: 1.0,
    };
    let out = Path::new("results");
    std::fs::create_dir_all(out)?;

    println!("== Table I ==\n{}", report::table1());
    println!("== Table II ==\n{}", report::table2());

    let t0 = std::time::Instant::now();
    let (points, all) = run_matrix(&opts, |r| {
        eprintln!(
            "  {:>14}  runtime {:>8}  fill {:>8}  release {:>9}{}",
            r.cell.label(),
            dur(r.runtime),
            dur(r.dispatch_span),
            dur(r.release_span),
            if r.unusable_in_production { "  [unusable in production]" } else { "" }
        );
    })?;
    println!(
        "\n== Table III == ({} runs in {:.1}s wall)\n",
        all.len(),
        t0.elapsed().as_secs_f64()
    );
    println!("{}", report::table3(&points));
    std::fs::write(out.join("table3.json"), report::results_json(&points).to_pretty())?;

    // Fig 1.
    println!("== Fig 1 (normalized overhead vs task time) ==\n");
    println!("{}", report::fig1_plot(&points));
    report::fig1_csv(&points).save(&out.join("fig1.csv"))?;

    // Fig 2.
    let med = median_runs(&all);
    let series: Vec<(String, llsched::metrics::timeline::UtilizationSeries)> = med
        .iter()
        .map(|r| (fig2_label(&r.cell), r.utilization.clone()))
        .collect();
    report::fig2_csv(&series).save(&out.join("fig2.csv"))?;
    let t60: Vec<_> = series.iter().filter(|(l, _)| l.ends_with("t60")).cloned().collect();
    println!("== Fig 2 (utilization vs time; t=60 median runs) ==\n");
    println!("{}", report::fig2_plot(&t60));

    // Paper-vs-measured comparison.
    println!("== paper vs measured (medians) ==\n");
    let mut cmp = llsched::util::fmt::Table::new(vec![
        "cell", "paper median", "measured median", "ratio",
    ]);
    for &(nodes, t, mode_s, paper) in PAPER_MEDIANS {
        if nodes > opts.max_nodes {
            continue;
        }
        let mode = if mode_s == "M" { Mode::MultiLevel } else { Mode::NodeBased };
        if let Some(p) = points
            .iter()
            .find(|p| p.nodes == nodes && p.task_time == t && p.mode == mode)
        {
            let m = p.median_runtime();
            cmp.row(vec![
                format!("{nodes}n/t={t}/{mode_s}*"),
                format!("{paper:.0}s"),
                format!("{m:.0}s"),
                format!("{:.2}x", m / paper),
            ]);
        }
    }
    println!("{}", cmp.render());

    // Headline speedup (512-node scale): M* is only measurable at t=60
    // (the paper's other cells are N/A); compare its overhead against
    // every N* task-time cell and report the range, as `llsched speedup`
    // does.
    if !quick {
        if let Some(m) = points
            .iter()
            .find(|p| p.nodes == 512 && p.task_time == 60.0 && p.mode == Mode::MultiLevel)
        {
            let ns: Vec<_> = points
                .iter()
                .filter(|p| p.nodes == 512 && p.mode == Mode::NodeBased)
                .collect();
            let med = ns.iter().map(|n| speedup(m, n, false)).fold(0.0, f64::max);
            let best = ns.iter().map(|n| speedup(m, n, true)).fold(0.0, f64::max);
            println!(
                "headline @512n: overhead ratio up to {med:.0}x median / {best:.0}x best (paper ~57x / ~100x)"
            );
        }
    }
    println!("\nresults written to {:?}", out);
    Ok(())
}
