//! Real-compute end-to-end: the full three-layer stack on actual
//! hardware. A node-based execution script (L3's generated artifact)
//! drives pinned worker lanes that execute *real* short-running
//! simulations — the AOT-compiled JAX/Pallas module (L2/L1) — through the
//! PJRT runtime, with checksums verified against the Python oracle.
//!
//! Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --example real_compute [-- --tasks N --iters K]
//! ```

use llsched::aggregation::script::build_scripts;
use llsched::coordinator::cli::Args;
use llsched::exec::payload::Payload;
use llsched::exec::worker::NodeExecutor;
use llsched::runtime::server::RuntimeServer;
use llsched::util::fmt::Table;
use std::sync::Arc;
use std::time::Instant;

fn main() -> llsched::Result<()> {
    // Flags only (no subcommand): prepend a dummy command for the parser.
    let args = Args::parse(
        std::iter::once("real_compute".to_string()).chain(std::env::args().skip(1)),
    )
    .unwrap_or_default();
    let tasks: u64 = args.opt_parse("tasks", 32)?;
    let iters: usize = args.opt_parse("iters", 2)?;
    let default_lanes = std::thread::available_parallelism()
        .map(|n| n.get() as u32)
        .unwrap_or(2);
    let lanes: u32 = args.opt_parse("lanes", default_lanes)?;

    let dir = llsched::runtime::find_artifacts_dir().ok_or_else(|| {
        llsched::Error::Runtime("artifacts/ not found — run `make artifacts`".into())
    })?;

    println!("three-layer end-to-end: {tasks} tasks × {iters} module invocations, {lanes} lanes\n");
    let mut table = Table::new(vec![
        "artifact",
        "tasks",
        "wall",
        "busy",
        "efficiency",
        "checksum fold",
    ]);
    for name in ["simstep_8x32x32", "simstep_4x64x64", "simstep_1x128x128"] {
        let server = Arc::new(RuntimeServer::spawn(dir.join(format!("{name}.hlo.txt")))?);
        // L3: the node-based script for one node with `lanes` cores.
        let script = &build_scripts(tasks, 1, lanes, 1)[0];
        let payload = Payload::Simulate { server: server.clone(), iters };
        let t0 = Instant::now();
        let rep = NodeExecutor::pinned().run(script, &payload)?;
        assert_eq!(rep.tasks_failed, 0, "all tasks must succeed");
        table.row(vec![
            name.to_string(),
            format!("{}", rep.tasks_run),
            format!("{:.2}s", t0.elapsed().as_secs_f64()),
            format!("{:.2}s", rep.busy_seconds),
            format!("{:.0}%", rep.efficiency() * 100.0),
            format!("{:#010x}", rep.checksum_fold),
        ]);
    }
    println!("{}", table.render());
    println!("every task ran the AOT-compiled Pallas simulation through PJRT;");
    println!("checksums are cross-checked against python in `cargo test`.");
    Ok(())
}
