//! Interactive-launch demo: the paper's §I claim that node-based
//! scheduling launches "large scale interactive jobs at a rate of over
//! 5000 jobs/second (260,000+ Matlab/Octave processes in under 40
//! seconds)".
//!
//! We reproduce the scenario: a 512-node interactive job with 64 worker
//! processes per node (32,768 processes — the machine slice of the
//! reference; the paper's 260k figure is the full 40k-core system with
//! multiple launches) submitted in triples mode, measuring processes
//! started per second of virtual time, and comparing with the per-core
//! and per-task styles.
//!
//! ```bash
//! cargo run --release --example interactive_launch
//! ```

use llsched::aggregation::plan::{ClusterShape, Workload};
use llsched::aggregation::for_mode;
use llsched::cluster::Cluster;
use llsched::config::Mode;
use llsched::scheduler::core::{SchedulerSim, TaskModel};
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::noise::NoiseModel;
use llsched::util::fmt::{count, dur, Table};

fn main() -> llsched::Result<()> {
    let nodes = 512u32;
    let shape = ClusterShape { nodes, cores_per_node: 64, task_mem_mib: 256 };
    // Interactive session: every core gets one long-lived worker process.
    let workers = shape.processors();
    let workload = Workload::Uniform { count: workers, duration: 600.0 };

    println!(
        "interactive launch: {} worker processes on {} nodes\n",
        count(workers),
        nodes
    );
    let mut table = Table::new(vec![
        "mode",
        "scheduling tasks",
        "time to full machine",
        "processes/sec",
    ]);
    for mode in [Mode::PerTask, Mode::MultiLevel, Mode::NodeBased] {
        let job = for_mode(mode).plan("interactive", &workload, &shape)?;
        let array = job.array_size();
        let sim = SchedulerSim::new(
            Cluster::tx_green(nodes),
            CostModel::slurm_like_tx_green(),
            NoiseModel::dedicated(),
            7,
        )
        .with_server_speed(1.0)
        .with_task_model(TaskModel {
            startup: 0.8,
            jitter_sigma: 0.0,
            p_node_late: 0.0,
            late_range: (0.0, 0.0),
        })
        .without_timeline();
        let (out, id) = sim.run_single(job);
        let stats = out.job_stats(id, 600.0).expect("finished");
        let fill = stats.dispatch_span + 0.8; // + startup
        table.row(vec![
            mode.to_string(),
            count(array),
            dur(fill),
            format!("{:.0}", workers as f64 / fill.max(1e-9)),
        ]);
    }
    println!("{}", table.render());
    println!("the paper's claim — >5000 processes/second, a full interactive");
    println!("machine in seconds — holds only for the node-based launch path.");
    Ok(())
}
