//! Quickstart: submit the same 2048-task workload three ways and watch
//! what the scheduler sees.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use llsched::aggregation::plan::{ClusterShape, Workload};
use llsched::aggregation::{for_mode, NodeBased};
use llsched::cluster::Cluster;
use llsched::config::Mode;
use llsched::scheduler::core::SchedulerSim;
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::noise::NoiseModel;
use llsched::util::fmt::{count, dur, Table};

fn main() -> llsched::Result<()> {
    // A small machine slice: 8 nodes × 64 cores.
    let shape = ClusterShape { nodes: 8, cores_per_node: 64, task_mem_mib: 256 };
    // The user workload: 2048 five-second simulation tasks (one per core,
    // 4 waves each → 20 s of work per processor).
    let workload = Workload::Uniform { count: 4 * shape.processors(), duration: 5.0 };
    println!(
        "workload: {} tasks × 5s on {} nodes × {} cores\n",
        count(workload.count()),
        shape.nodes,
        shape.cores_per_node
    );

    let mut table = Table::new(vec![
        "mode",
        "scheduling tasks",
        "runtime",
        "overhead",
        "fill time",
        "release span",
    ]);
    for mode in [Mode::PerTask, Mode::MultiLevel, Mode::NodeBased] {
        let job = for_mode(mode).plan("quickstart", &workload, &shape)?;
        let array = job.array_size();
        let sim = SchedulerSim::new(
            Cluster::tx_green(shape.nodes),
            CostModel::slurm_like_tx_green(),
            NoiseModel::dedicated(),
            42,
        )
        .with_server_speed(1.0);
        let (out, id) = sim.run_single(job);
        let stats = out.job_stats(id, 20.0).expect("job finished");
        table.row(vec![
            mode.to_string(),
            count(array),
            dur(stats.runtime),
            dur(stats.overhead),
            dur(stats.dispatch_span),
            dur(stats.release_span),
        ]);
    }
    println!("{}", table.render());
    println!("node-based (the paper's triples mode) reduces the scheduler-visible");
    println!("array from one task per compute task (or per core) to one per node —");
    println!("dispatch and cleanup shrink proportionally.\n");

    // Peek at a generated node execution script (the real artifact the
    // scheduler would run on each node).
    let nb = NodeBased::default();
    let script = &nb.scripts(&workload, &shape)[0];
    println!(
        "generated node script for array index 0 ({} tasks over {} lanes):\n",
        script.total_tasks(),
        script.lanes.len()
    );
    let text = script.render("./sim_task");
    for line in text.lines().take(12) {
        println!("  {line}");
    }
    println!("  ...");
    Ok(())
}
